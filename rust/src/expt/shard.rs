//! Distributed sweeps: shard a [`SweepGrid`] across processes/machines,
//! serialize partial results, and merge them back — bit-identical to an
//! unsharded run.
//!
//! Cells are independent deterministic simulations, so distribution is a
//! partition of grid indices: shard `i/N` owns every cell with
//! `index % N == i`.  Each shard writes a JSON file (via `util::json`)
//! carrying a **grid fingerprint** — a hash of the full grid definition
//! (base config, seeds, schedulers, workloads, engine options) — plus one
//! integer-only [`CellSummary`] per cell.  `merge_shards` refuses to
//! combine files whose fingerprints differ (two machines silently running
//! different grids is the classic distributed-sweep failure), checks that
//! every shard of the partition is present exactly once, and reassembles
//! the full grid by index.  Because summaries are integers and every
//! derived statistic is recomputed from them by the same code, the merged
//! report is byte-identical to a single-process run — proven by
//! `tests/golden_determinism.rs` for N ∈ {2, 3} over all five schedulers.

use crate::expt::experiments::SMALL_DEMAND;
use crate::expt::paper::{self, SweepClaimCheck};
use crate::expt::sweep::{run_cells, SweepGrid};
use crate::metrics::{compare_small_large, JobMetrics, SmallLargeComparison};
use crate::report::{self, StatsRow};
use crate::sim::RunResult;
use crate::util::json::Json;
use crate::util::stats::Ci95;

/// Tag every shard file carries; guards against feeding arbitrary JSON in.
pub const SHARD_FORMAT: &str = "dress-sweep-shard";
/// Bumped whenever the shard schema changes incompatibly.
/// v2: fault/recovery counters (lost attempts, lost/wasted/useful work,
/// outage count) joined the cell summary.
/// v3: federation counters (simulation cells, migrations, cell outages,
/// summed recovery latency, imbalance milli-ratios) joined the cell
/// summary.
pub const SHARD_VERSION: u64 = 3;

// ------------------------------------------------------------ fingerprint

/// FNV-1a 64-bit hash (zero-dependency, stable across platforms).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fingerprint of the *entire* grid definition.  Two shard files combine
/// only if they hashed the same base config, seeds, schedulers, workloads
/// and engine options — any drift (a config default change, a different
/// seed list, a new sink policy) changes the fingerprint and the merge
/// rejects the stale file instead of silently mixing grids.
pub fn grid_fingerprint(grid: &SweepGrid) -> String {
    let canon = format!(
        "base={:?};seeds={:?};scheds={:?};workloads={:?};opts={:?}",
        grid.base, grid.seeds, grid.scheds, grid.workloads, grid.opts
    );
    format!("{:016x}", fnv1a64(canon.as_bytes()))
}

// ------------------------------------------------------------- shard spec

/// One shard of an `N`-way partition: owns cells with
/// `index % count == self.index`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub index: usize,
    pub count: usize,
}

impl ShardSpec {
    /// The trivial partition — one shard owning every cell.
    pub fn full() -> ShardSpec {
        ShardSpec { index: 0, count: 1 }
    }

    /// Parse the CLI form `i/N` (e.g. `0/3`).
    pub fn parse(s: &str) -> Result<ShardSpec, String> {
        let (i, n) = s
            .split_once('/')
            .ok_or_else(|| format!("--shard takes `i/N` (e.g. 0/3), got `{s}`"))?;
        let index: usize =
            i.trim().parse().map_err(|e| format!("--shard index `{i}`: {e}"))?;
        let count: usize =
            n.trim().parse().map_err(|e| format!("--shard count `{n}`: {e}"))?;
        if count == 0 {
            return Err("--shard count must be >= 1".into());
        }
        if index >= count {
            return Err(format!("--shard index {index} out of range for {count} shards"));
        }
        Ok(ShardSpec { index, count })
    }

    /// Does this shard own grid cell `idx`?
    pub fn owns(&self, idx: usize) -> bool {
        idx % self.count == self.index
    }

    /// The grid indices this shard owns, ascending.
    pub fn indices(&self, grid_len: usize) -> Vec<usize> {
        (0..grid_len).filter(|&i| self.owns(i)).collect()
    }
}

// -------------------------------------------------------------- grid meta

/// What kind of report the grid feeds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SweepMode {
    /// Generic seed × scheduler table + per-scheduler aggregates.
    Grid,
    /// The paper-claim pair grid (`expt::sweep::paper_grid`): adds the
    /// FIG7/FIG9/TAB2 `mean ± CI` claim verification section.
    Paper,
}

impl SweepMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            SweepMode::Grid => "grid",
            SweepMode::Paper => "paper",
        }
    }

    pub fn parse(s: &str) -> Result<SweepMode, String> {
        match s {
            "grid" => Ok(SweepMode::Grid),
            "paper" => Ok(SweepMode::Paper),
            other => Err(format!("unknown sweep mode `{other}`")),
        }
    }
}

/// The grid description a shard file carries: enough to lay cells back
/// out by index and render the final report, without rebuilding the
/// workloads.  Equality (including the fingerprint) is the merge
/// compatibility check.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepMeta {
    pub mode: SweepMode,
    pub fingerprint: String,
    pub seeds: Vec<u64>,
    /// Scheduler names in grid order (e.g. `["dress", "capacity"]`).
    pub scheds: Vec<String>,
    /// One human-readable label per workload axis point.
    pub workloads: Vec<String>,
}

impl SweepMeta {
    pub fn of(grid: &SweepGrid, mode: SweepMode) -> SweepMeta {
        SweepMeta {
            mode,
            fingerprint: grid_fingerprint(grid),
            seeds: grid.seeds.clone(),
            scheds: grid.scheds.iter().map(|k| k.name().to_string()).collect(),
            workloads: grid.workloads.iter().map(|w| format!("{w:?}")).collect(),
        }
    }

    /// Total number of grid cells.
    pub fn cells(&self) -> usize {
        self.workloads.len() * self.scheds.len() * self.seeds.len()
    }

    /// Grid index of (workload, sched, seed) — same layout as
    /// [`SweepGrid::index`] (workload-major, seed-minor).
    pub fn index(&self, workload: usize, sched: usize, seed: usize) -> usize {
        (workload * self.scheds.len() + sched) * self.seeds.len() + seed
    }

    /// Inverse of [`Self::index`].
    pub fn point(&self, idx: usize) -> (usize, usize, usize) {
        let per_workload = self.scheds.len() * self.seeds.len();
        (
            idx / per_workload,
            (idx % per_workload) / self.seeds.len(),
            idx % self.seeds.len(),
        )
    }
}

// ----------------------------------------------------------- cell summary

/// The serialized result of one grid cell.  Deliberately integer-only
/// (per-job metrics + whole-run counters): floats never cross the wire,
/// so a JSON round-trip is exact and every derived statistic (averages,
/// CIs, claim checks) is recomputed from identical inputs by identical
/// code — the foundation of the byte-identical merge guarantee.
///
/// Utilization crosses the wire the same way: the exact integer terms of
/// the time-weighted integral (`util_area_ms / util_span_ms / total`), not
/// the derived fraction, so a merged report divides the identical integers
/// a single-process run divides.
#[derive(Debug, Clone, PartialEq)]
pub struct CellSummary {
    pub index: usize,
    pub seed: u64,
    pub scheduler: String,
    pub makespan_ms: u64,
    pub events: u64,
    pub sched_ticks: u64,
    pub failures: u32,
    pub tasks_recorded: u64,
    /// Cluster capacity the utilization integers are relative to.
    pub total_containers: u32,
    /// Per-tick samples observed (sink-independent).
    pub util_samples: u64,
    /// `t_last − t_first` of the utilization sample stream.
    pub util_span_ms: u64,
    /// `Σ used·Δt` — container-milliseconds of occupancy.
    pub util_area_ms: u64,
    /// `Σ used` (unweighted fallback term).
    pub util_sum_used: u64,
    /// Max containers simultaneously busy.
    pub util_peak: u32,
    /// Container attempts started over the whole run.
    pub attempts: u32,
    /// Attempts killed by node crashes (fault plan).
    pub lost_attempts: u32,
    /// Run-time thrown away by crashes, ms.
    pub lost_work_ms: u64,
    /// Total wasted run-time (crashes plus ordinary task failures), ms.
    pub wasted_work_ms: u64,
    /// Run-time of attempts that completed, ms.
    pub useful_work_ms: u64,
    /// Node outages that fired during the run.
    pub outages: u32,
    /// Simulation cells behind this result (1 = plain engine run, >1 =
    /// a federated run merged by `federation::FederationResult::merged`).
    pub fed_cells: u32,
    /// Cross-cell migrations (threshold rebalancing + death salvage).
    pub migrations: u32,
    /// Cell-level outages that fired during the run.
    pub cell_outages: u32,
    /// Σ time-to-recover over *healed* cell outages, ms (unhealed outages
    /// contribute nothing — they have no finite latency to sum).
    pub cell_recover_ms: u64,
    /// Peak cross-cell imbalance ratio in exact milli-units
    /// (`round(ratio × 1000)`): integers cross the wire, floats do not.
    pub imbalance_max_milli: u64,
    /// Time-mean imbalance ratio in the same milli-units.
    pub imbalance_mean_milli: u64,
    pub jobs: Vec<JobMetrics>,
}

impl CellSummary {
    pub fn of(grid: &SweepGrid, index: usize, r: &RunResult) -> CellSummary {
        let p = grid.point(index);
        CellSummary {
            index,
            seed: grid.seeds[p.seed],
            scheduler: r.scheduler.clone(),
            makespan_ms: r.system.makespan_ms,
            events: r.events,
            sched_ticks: r.sched_ticks,
            failures: r.failures,
            tasks_recorded: r.tasks_recorded,
            total_containers: r.util.total,
            util_samples: r.util.samples,
            util_span_ms: r.util.span_ms,
            util_area_ms: r.util.area_ms,
            util_sum_used: r.util.sum_used,
            util_peak: r.util.peak_used,
            attempts: r.attempts,
            lost_attempts: r.lost_attempts,
            lost_work_ms: r.lost_work_ms,
            wasted_work_ms: r.wasted_work_ms,
            useful_work_ms: r.useful_work_ms,
            outages: r.outages.len() as u32,
            fed_cells: r.cells,
            migrations: r.migrations,
            cell_outages: r.cell_outages.len() as u32,
            cell_recover_ms: r
                .cell_outages
                .iter()
                .filter_map(|o| o.time_to_recover_ms())
                .sum(),
            imbalance_max_milli: (r.imbalance_max * 1000.0).round() as u64,
            imbalance_mean_milli: (r.imbalance_mean * 1000.0).round() as u64,
            jobs: r.jobs.clone(),
        }
    }

    /// Goodput recomputed from the wire integers — exactly the fraction
    /// the originating [`RunResult::goodput`] computed.
    pub fn goodput(&self) -> f64 {
        let denom = self.useful_work_ms + self.wasted_work_ms;
        if denom == 0 {
            1.0
        } else {
            self.useful_work_ms as f64 / denom as f64
        }
    }

    /// The exact utilization summary reassembled from the wire integers.
    pub fn util(&self) -> crate::metrics::UtilSummary {
        crate::metrics::UtilSummary::from_parts(
            self.total_containers,
            self.util_samples,
            self.util_span_ms,
            self.util_area_ms,
            self.util_sum_used,
            self.util_peak,
        )
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("index", Json::Num(self.index as f64));
        o.set("seed", Json::Num(self.seed as f64));
        o.set("scheduler", Json::Str(self.scheduler.clone()));
        o.set("makespan_ms", Json::Num(self.makespan_ms as f64));
        o.set("events", Json::Num(self.events as f64));
        o.set("sched_ticks", Json::Num(self.sched_ticks as f64));
        o.set("failures", Json::Num(self.failures as f64));
        o.set("tasks_recorded", Json::Num(self.tasks_recorded as f64));
        o.set("total_containers", Json::Num(self.total_containers as f64));
        o.set("util_samples", Json::Num(self.util_samples as f64));
        o.set("util_span_ms", Json::Num(self.util_span_ms as f64));
        o.set("util_area_ms", Json::Num(self.util_area_ms as f64));
        o.set("util_sum_used", Json::Num(self.util_sum_used as f64));
        o.set("util_peak", Json::Num(self.util_peak as f64));
        o.set("attempts", Json::Num(self.attempts as f64));
        o.set("lost_attempts", Json::Num(self.lost_attempts as f64));
        o.set("lost_work_ms", Json::Num(self.lost_work_ms as f64));
        o.set("wasted_work_ms", Json::Num(self.wasted_work_ms as f64));
        o.set("useful_work_ms", Json::Num(self.useful_work_ms as f64));
        o.set("outages", Json::Num(self.outages as f64));
        o.set("fed_cells", Json::Num(self.fed_cells as f64));
        o.set("migrations", Json::Num(self.migrations as f64));
        o.set("cell_outages", Json::Num(self.cell_outages as f64));
        o.set("cell_recover_ms", Json::Num(self.cell_recover_ms as f64));
        o.set("imbalance_max_milli", Json::Num(self.imbalance_max_milli as f64));
        o.set("imbalance_mean_milli", Json::Num(self.imbalance_mean_milli as f64));
        let jobs: Vec<Json> = self
            .jobs
            .iter()
            .map(|j| {
                let mut jo = Json::obj();
                jo.set("id", Json::Num(j.id as f64));
                jo.set("demand", Json::Num(j.demand as f64));
                jo.set("submit_ms", Json::Num(j.submit_ms as f64));
                jo.set("waiting_ms", Json::Num(j.waiting_ms as f64));
                jo.set("completion_ms", Json::Num(j.completion_ms as f64));
                jo.set("execution_ms", Json::Num(j.execution_ms as f64));
                jo
            })
            .collect();
        o.set("jobs", Json::Arr(jobs));
        o
    }

    fn from_json(v: &Json) -> Result<CellSummary, String> {
        let jobs = arr_field(v, "jobs")?
            .iter()
            .enumerate()
            .map(|(k, jv)| {
                let waiting_ms = u64_field(jv, "waiting_ms")?;
                let completion_ms = u64_field(jv, "completion_ms")?;
                let execution_ms = u64_field(jv, "execution_ms")?;
                if completion_ms.checked_sub(waiting_ms) != Some(execution_ms) {
                    return Err(format!(
                        "job {k}: execution_ms {execution_ms} != completion {completion_ms} - waiting {waiting_ms}"
                    ));
                }
                Ok(JobMetrics {
                    id: u64_field(jv, "id")? as u32,
                    demand: u64_field(jv, "demand")? as u32,
                    submit_ms: u64_field(jv, "submit_ms")?,
                    waiting_ms,
                    completion_ms,
                    execution_ms,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        let total_containers = u64_field(v, "total_containers")? as u32;
        let util_span_ms = u64_field(v, "util_span_ms")?;
        let util_area_ms = u64_field(v, "util_area_ms")?;
        let util_peak = u64_field(v, "util_peak")? as u32;
        // The integral cannot exceed full occupancy over the whole span
        // (u128: span·total overflows u64 for pathological inputs).
        if util_peak > total_containers {
            return Err(format!(
                "util_peak {util_peak} exceeds total_containers {total_containers}"
            ));
        }
        if util_area_ms as u128 > util_span_ms as u128 * total_containers as u128 {
            return Err(format!(
                "util_area_ms {util_area_ms} exceeds {util_span_ms}·{total_containers} \
                 (occupancy above capacity)"
            ));
        }
        let attempts = u64_field(v, "attempts")? as u32;
        let lost_attempts = u64_field(v, "lost_attempts")? as u32;
        let lost_work_ms = u64_field(v, "lost_work_ms")?;
        let wasted_work_ms = u64_field(v, "wasted_work_ms")?;
        if lost_attempts > attempts {
            return Err(format!("lost_attempts {lost_attempts} exceeds attempts {attempts}"));
        }
        if lost_work_ms > wasted_work_ms {
            return Err(format!(
                "lost_work_ms {lost_work_ms} exceeds wasted_work_ms {wasted_work_ms} \
                 (crash losses are a subset of waste)"
            ));
        }
        let fed_cells = u64_field(v, "fed_cells")? as u32;
        let migrations = u64_field(v, "migrations")? as u32;
        let cell_outages = u64_field(v, "cell_outages")? as u32;
        if fed_cells == 0 {
            return Err("fed_cells must be >= 1".into());
        }
        if fed_cells == 1 && (migrations > 0 || cell_outages > 0) {
            return Err(format!(
                "single-cell run carries federation counters \
                 (migrations {migrations}, cell_outages {cell_outages})"
            ));
        }
        Ok(CellSummary {
            index: u64_field(v, "index")? as usize,
            seed: u64_field(v, "seed")?,
            scheduler: str_field(v, "scheduler")?.to_string(),
            makespan_ms: u64_field(v, "makespan_ms")?,
            events: u64_field(v, "events")?,
            sched_ticks: u64_field(v, "sched_ticks")?,
            failures: u64_field(v, "failures")? as u32,
            tasks_recorded: u64_field(v, "tasks_recorded")?,
            total_containers,
            util_samples: u64_field(v, "util_samples")?,
            util_span_ms,
            util_area_ms,
            util_sum_used: u64_field(v, "util_sum_used")?,
            util_peak,
            attempts,
            lost_attempts,
            lost_work_ms,
            wasted_work_ms,
            useful_work_ms: u64_field(v, "useful_work_ms")?,
            outages: u64_field(v, "outages")? as u32,
            fed_cells,
            migrations,
            cell_outages,
            cell_recover_ms: u64_field(v, "cell_recover_ms")?,
            imbalance_max_milli: u64_field(v, "imbalance_max_milli")?,
            imbalance_mean_milli: u64_field(v, "imbalance_mean_milli")?,
            jobs,
        })
    }
}

// ------------------------------------------------------------ shard files

/// One parsed shard file: grid meta + the cells this shard owns.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardFile {
    pub meta: SweepMeta,
    pub shard: ShardSpec,
    pub cells: Vec<CellSummary>,
}

/// Run the cells shard `spec` owns on `workers` threads and summarize.
pub fn run_shard(grid: &SweepGrid, spec: ShardSpec, workers: usize) -> Vec<CellSummary> {
    let indices = spec.indices(grid.len());
    run_cells(grid, &indices, workers)
        .into_iter()
        .map(|(i, r)| CellSummary::of(grid, i, &r))
        .collect()
}

/// Serialize one shard's results (`dress sweep --shard i/N --out f.json`).
pub fn shard_to_json(meta: &SweepMeta, spec: ShardSpec, cells: &[CellSummary]) -> Json {
    let mut o = Json::obj();
    o.set("format", Json::Str(SHARD_FORMAT.into()));
    o.set("version", Json::Num(SHARD_VERSION as f64));
    o.set("mode", Json::Str(meta.mode.as_str().into()));
    o.set("fingerprint", Json::Str(meta.fingerprint.clone()));
    o.set("seeds", Json::Arr(meta.seeds.iter().map(|&s| Json::Num(s as f64)).collect()));
    o.set("scheds", Json::Arr(meta.scheds.iter().map(|s| Json::Str(s.clone())).collect()));
    o.set(
        "workloads",
        Json::Arr(meta.workloads.iter().map(|w| Json::Str(w.clone())).collect()),
    );
    o.set("shard_index", Json::Num(spec.index as f64));
    o.set("shard_count", Json::Num(spec.count as f64));
    o.set("cells", Json::Arr(cells.iter().map(CellSummary::to_json).collect()));
    o
}

/// Parse + validate one shard file: format/version tags, internally
/// consistent meta, and cells that are exactly the owned index set with
/// the scheduler/seed the grid layout assigns to each index.
pub fn shard_from_json(v: &Json) -> Result<ShardFile, String> {
    let format = str_field(v, "format")?;
    if format != SHARD_FORMAT {
        return Err(format!("not a sweep shard file (format `{format}`)"));
    }
    let version = u64_field(v, "version")?;
    if version != SHARD_VERSION {
        return Err(format!("unsupported shard version {version} (expected {SHARD_VERSION})"));
    }
    let meta = SweepMeta {
        mode: SweepMode::parse(str_field(v, "mode")?)?,
        fingerprint: str_field(v, "fingerprint")?.to_string(),
        seeds: arr_field(v, "seeds")?
            .iter()
            .map(|s| s.as_f64().map(|n| n as u64).ok_or_else(|| "non-numeric seed".to_string()))
            .collect::<Result<Vec<_>, _>>()?,
        scheds: str_arr_field(v, "scheds")?,
        workloads: str_arr_field(v, "workloads")?,
    };
    if meta.seeds.is_empty() || meta.scheds.is_empty() || meta.workloads.is_empty() {
        return Err("empty grid axis in shard meta".into());
    }
    let shard = ShardSpec {
        index: u64_field(v, "shard_index")? as usize,
        count: u64_field(v, "shard_count")? as usize,
    };
    if shard.count == 0 || shard.index >= shard.count {
        return Err(format!("bad shard spec {}/{}", shard.index, shard.count));
    }
    let cells = arr_field(v, "cells")?
        .iter()
        .map(CellSummary::from_json)
        .collect::<Result<Vec<_>, _>>()?;
    let expected = shard.indices(meta.cells());
    let got: Vec<usize> = cells.iter().map(|c| c.index).collect();
    if got != expected {
        return Err(format!(
            "shard {}/{} cells {:?} != owned indices {:?}",
            shard.index, shard.count, got, expected
        ));
    }
    for c in &cells {
        let (_, k, s) = meta.point(c.index);
        if c.scheduler != meta.scheds[k] {
            return Err(format!(
                "cell {}: scheduler `{}` but grid layout says `{}`",
                c.index, c.scheduler, meta.scheds[k]
            ));
        }
        if c.seed != meta.seeds[s] {
            return Err(format!(
                "cell {}: seed {} but grid layout says {}",
                c.index, c.seed, meta.seeds[s]
            ));
        }
    }
    Ok(ShardFile { meta, shard, cells })
}

/// Merge a complete set of shard files back into the full grid.
///
/// Validates that every file describes the *same* grid (meta equality,
/// which includes the fingerprint), that all files agree on the partition
/// width, and that shards `0..count` are each present exactly once; then
/// reassembles cells by grid index.  The result is indistinguishable from
/// summarizing an unsharded `run_sweep`.
pub fn merge_shards(files: Vec<ShardFile>) -> Result<(SweepMeta, Vec<CellSummary>), String> {
    let (meta, count, seen) = validate_shard_set(&files)?;
    let missing: Vec<usize> =
        seen.iter().enumerate().filter(|(_, &s)| !s).map(|(i, _)| i).collect();
    if !missing.is_empty() {
        return Err(format!(
            "incomplete merge: missing shards {missing:?} of /{count} \
             (pass --partial to merge what survived)"
        ));
    }
    let mut cells: Vec<CellSummary> = files.into_iter().flat_map(|f| f.cells).collect();
    cells.sort_by_key(|c| c.index);
    assert_eq!(cells.len(), meta.cells(), "validated shards cannot under-cover the grid");
    Ok((meta, cells))
}

/// Shared validation for both merge flavors: every file must describe the
/// same grid (meta equality includes the fingerprint) and the same
/// partition width, with each shard index in range and present at most
/// once.  Returns which shard indices are present.
fn validate_shard_set(files: &[ShardFile]) -> Result<(SweepMeta, usize, Vec<bool>), String> {
    let first = files.first().ok_or("no shard files to merge")?;
    let meta = first.meta.clone();
    let count = first.shard.count;
    for f in files {
        if f.meta != meta {
            return Err(format!(
                "shard grid mismatch: fingerprint {} vs {} — these files came from different \
                 sweep definitions and cannot be merged",
                f.meta.fingerprint, meta.fingerprint
            ));
        }
        if f.shard.count != count {
            return Err(format!(
                "partition width mismatch: shard {}/{} vs expected /{count}",
                f.shard.index, f.shard.count
            ));
        }
    }
    let mut seen = vec![false; count];
    for f in files {
        if f.shard.index >= count {
            return Err(format!("shard index {} out of range for /{count}", f.shard.index));
        }
        if seen[f.shard.index] {
            return Err(format!("duplicate shard {}/{count}", f.shard.index));
        }
        seen[f.shard.index] = true;
    }
    Ok((meta, count, seen))
}

/// What a (possibly incomplete) shard set covers of its grid.
#[derive(Debug, Clone, PartialEq)]
pub struct Coverage {
    /// Partition width the shards were swept with.
    pub shard_count: usize,
    pub shards_present: Vec<usize>,
    pub shards_missing: Vec<usize>,
    /// Grid indices no surviving shard carries, ascending.
    pub missing_cells: Vec<usize>,
    pub total_cells: usize,
}

impl Coverage {
    pub fn is_complete(&self) -> bool {
        self.shards_missing.is_empty()
    }

    pub fn present_cells(&self) -> usize {
        self.total_cells - self.missing_cells.len()
    }
}

/// Merge an *incomplete* shard set (`dress sweep-merge --partial`): the
/// same grid/partition validation as [`merge_shards`], but missing shards
/// are tolerated and reported in the returned [`Coverage`] instead of
/// rejected.  Cells come back sorted by grid index with holes where the
/// missing shards were.
pub fn merge_shards_partial(
    files: Vec<ShardFile>,
) -> Result<(SweepMeta, Vec<CellSummary>, Coverage), String> {
    let (meta, count, seen) = validate_shard_set(&files)?;
    let shards_present: Vec<usize> =
        seen.iter().enumerate().filter(|(_, &s)| s).map(|(i, _)| i).collect();
    let shards_missing: Vec<usize> =
        seen.iter().enumerate().filter(|(_, &s)| !s).map(|(i, _)| i).collect();
    let mut cells: Vec<CellSummary> = files.into_iter().flat_map(|f| f.cells).collect();
    cells.sort_by_key(|c| c.index);
    let mut have = cells.iter().map(|c| c.index).peekable();
    let mut missing_cells = Vec::new();
    for idx in 0..meta.cells() {
        if have.peek() == Some(&idx) {
            have.next();
        } else {
            missing_cells.push(idx);
        }
    }
    let cov = Coverage {
        shard_count: count,
        shards_present,
        shards_missing,
        missing_cells,
        total_cells: meta.cells(),
    };
    Ok((meta, cells, cov))
}

// ---------------------------------------------------------------- reports

/// DRESS-vs-baseline comparisons for one workload, one per seed, rebuilt
/// from cell summaries (requires a 2-scheduler grid containing `dress`).
pub fn pair_comparisons(
    meta: &SweepMeta,
    cells: &[CellSummary],
    workload: usize,
) -> Vec<SmallLargeComparison> {
    assert_eq!(meta.scheds.len(), 2, "pair comparisons need a 2-scheduler grid");
    let di = meta
        .scheds
        .iter()
        .position(|s| s == "dress")
        .expect("pair comparisons need a dress row");
    let bi = 1 - di;
    (0..meta.seeds.len())
        .map(|s| {
            let d = &cells[meta.index(workload, di, s)];
            let b = &cells[meta.index(workload, bi, s)];
            compare_small_large(&d.jobs, &b.jobs, d.makespan_ms, b.makespan_ms, SMALL_DEMAND)
        })
        .collect()
}

/// Seed aggregates per (workload, scheduler): makespan, average waiting,
/// time-weighted utilization and goodput as 95% CIs across the seed axis.
///
/// Tolerates sparse cell sets (partial merges): absent seeds simply drop
/// out of a group's sample (`n` in the output reflects what survived),
/// and a group with no surviving cells is omitted.  On a complete grid
/// this is byte-identical to the historical full-grid behavior.
pub fn sweep_stat_rows(meta: &SweepMeta, cells: &[CellSummary]) -> Vec<StatsRow> {
    let mut by_index: Vec<Option<&CellSummary>> = vec![None; meta.cells()];
    for c in cells {
        by_index[c.index] = Some(c);
    }
    let mut rows = Vec::new();
    for (w, _) in meta.workloads.iter().enumerate() {
        for (k, sched) in meta.scheds.iter().enumerate() {
            let mut makespans = Vec::with_capacity(meta.seeds.len());
            let mut waits = Vec::with_capacity(meta.seeds.len());
            let mut utils = Vec::with_capacity(meta.seeds.len());
            let mut goodputs = Vec::with_capacity(meta.seeds.len());
            for s in 0..meta.seeds.len() {
                let Some(c) = by_index[meta.index(w, k, s)] else { continue };
                makespans.push(c.makespan_ms as f64 / 1000.0);
                waits.push(avg_wait_s(c));
                utils.push(100.0 * c.util().mean_utilization());
                goodputs.push(c.goodput());
            }
            if makespans.is_empty() {
                continue;
            }
            let group = format!("w{w}/{sched}");
            rows.push(StatsRow {
                group: group.clone(),
                metric: "makespan_s".into(),
                ci: Ci95::of(&makespans),
            });
            rows.push(StatsRow {
                group: group.clone(),
                metric: "avg_wait_s".into(),
                ci: Ci95::of(&waits),
            });
            rows.push(StatsRow {
                group: group.clone(),
                metric: "util_pct".into(),
                ci: Ci95::of(&utils),
            });
            rows.push(StatsRow { group, metric: "goodput".into(), ci: Ci95::of(&goodputs) });
        }
    }
    rows
}

/// The FIG7/FIG9/TAB2 claim checks for a paper-mode grid.
pub fn sweep_claim_checks(meta: &SweepMeta, cells: &[CellSummary]) -> Vec<SweepClaimCheck> {
    assert_eq!(meta.mode, SweepMode::Paper, "claim checks need a paper-mode sweep");
    assert_eq!(meta.workloads.len(), 2, "paper grid is [spark, mapreduce]");
    let spark = pair_comparisons(meta, cells, 0);
    let mr = pair_comparisons(meta, cells, 1);
    paper::evaluate_sweep_claims(&spark, &mr)
}

/// The per-cell table shared by the full and partial reports.
fn cell_table(meta: &SweepMeta, cells: &[CellSummary]) -> String {
    let header = [
        "Cell", "Wkld", "Seed", "Scheduler", "Makespan (s)", "Avg wait (s)", "Util (%)",
        "Events", "Lost", "Migr", "Goodput",
    ];
    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            let (w, _, _) = meta.point(c.index);
            vec![
                c.index.to_string(),
                format!("w{w}"),
                c.seed.to_string(),
                c.scheduler.clone(),
                format!("{:.1}", c.makespan_ms as f64 / 1000.0),
                format!("{:.1}", avg_wait_s(c)),
                format!("{:.1}", 100.0 * c.util().mean_utilization()),
                c.events.to_string(),
                c.lost_attempts.to_string(),
                c.migrations.to_string(),
                format!("{:.3}", c.goodput()),
            ]
        })
        .collect();
    report::render_table(&header, &rows)
}

/// Render the degraded report for a partial merge: the coverage section
/// (which shards and grid cells survived) followed by the per-cell table
/// and seed aggregates over the surviving cells only.  Paper-mode claim
/// checks need complete DRESS/baseline pairs, so they are skipped with a
/// note rather than judged on holes.
pub fn render_partial_sweep_report(
    meta: &SweepMeta,
    cells: &[CellSummary],
    cov: &Coverage,
) -> String {
    let mut out = format!(
        "partial sweep report: {} seeds x {} schedulers x {} workloads = {} cells ({})\n",
        meta.seeds.len(),
        meta.scheds.len(),
        meta.workloads.len(),
        meta.cells(),
        meta.mode.as_str(),
    );
    out.push_str(&format!("grid fingerprint: {}\n", meta.fingerprint));
    for (w, label) in meta.workloads.iter().enumerate() {
        out.push_str(&format!("workload {w}: {label}\n"));
    }
    out.push('\n');

    out.push_str(&format!(
        "coverage: {}/{} shards present, {}/{} cells\n",
        cov.shards_present.len(),
        cov.shard_count,
        cov.present_cells(),
        cov.total_cells,
    ));
    out.push_str(&format!("  shards present: {:?}\n", cov.shards_present));
    if cov.is_complete() {
        out.push_str("  all shards present — the partition is complete\n");
    } else {
        out.push_str(&format!("  shards missing: {:?}\n", cov.shards_missing));
        out.push_str("  missing cells (by grid index):\n");
        for &idx in &cov.missing_cells {
            let (w, k, s) = meta.point(idx);
            out.push_str(&format!(
                "    cell {idx} = w{w}/{}/seed {}\n",
                meta.scheds[k], meta.seeds[s]
            ));
        }
    }
    out.push('\n');

    out.push_str(&cell_table(meta, cells));
    out.push('\n');

    out.push_str("seed aggregates over surviving cells (Student-t 95% CI; n varies):\n");
    out.push_str(&report::stats_table(&sweep_stat_rows(meta, cells)));

    if meta.mode == SweepMode::Paper {
        out.push('\n');
        if cov.is_complete() {
            let checks = sweep_claim_checks(meta, cells);
            out.push_str("paper claims (pass/fail on the 95% CI bound):\n");
            for c in &checks {
                let (row, _) = report::comparison_row_ci(&c.claim, &c.ci);
                out.push_str(&row);
                out.push('\n');
            }
        } else {
            out.push_str(
                "paper claims: skipped — claim CIs need complete DRESS/baseline \
                 pairs on every seed (merge the missing shards and re-run)\n",
            );
        }
    }
    out
}

fn avg_wait_s(c: &CellSummary) -> f64 {
    let w: Vec<f64> = c.jobs.iter().map(|j| j.waiting_ms as f64).collect();
    crate::util::stats::mean(&w) / 1000.0
}

/// Render the canonical sweep report: grid header, per-cell table, seed
/// aggregates (`mean/ci_lo/ci_hi/n_seeds`), and — in paper mode — the
/// claim-verification section judged on the CI bound.
///
/// Everything here is a pure function of `(meta, cells)`, so a merged
/// multi-machine run prints byte-for-byte what a single process prints —
/// the property the CI sweep matrix asserts with `cmp`.
pub fn render_sweep_report(meta: &SweepMeta, cells: &[CellSummary]) -> String {
    assert_eq!(cells.len(), meta.cells(), "report needs the complete grid");
    let mut out = format!(
        "sweep report: {} seeds x {} schedulers x {} workloads = {} cells ({})\n",
        meta.seeds.len(),
        meta.scheds.len(),
        meta.workloads.len(),
        meta.cells(),
        meta.mode.as_str(),
    );
    out.push_str(&format!("grid fingerprint: {}\n", meta.fingerprint));
    for (w, label) in meta.workloads.iter().enumerate() {
        out.push_str(&format!("workload {w}: {label}\n"));
    }
    out.push('\n');

    out.push_str(&cell_table(meta, cells));
    out.push('\n');

    out.push_str("seed aggregates (Student-t 95% CI):\n");
    out.push_str(&report::stats_table(&sweep_stat_rows(meta, cells)));

    if meta.mode == SweepMode::Paper {
        let checks = sweep_claim_checks(meta, cells);
        out.push('\n');
        out.push_str("paper claims (pass/fail on the 95% CI bound):\n");
        let mut all_ok = true;
        for c in &checks {
            let (row, ok) = report::comparison_row_ci(&c.claim, &c.ci);
            out.push_str(&row);
            out.push('\n');
            all_ok &= ok;
        }
        let lanes: Vec<(String, Ci95)> =
            checks.iter().map(|c| (c.claim.id.clone(), c.ci)).collect();
        out.push_str(&report::fig_ci_bars("claim CIs (change vs baseline, %)", &lanes, 44));
        out.push_str(&format!(
            "sweep shape: {}\n",
            if all_ok { "ALL CLAIMS HOLD" } else { "SOME CLAIMS MISSED" }
        ));
    }
    out
}

// ------------------------------------------------------------ json access

fn u64_field(v: &Json, key: &str) -> Result<u64, String> {
    let n = v
        .get(key)
        .and_then(|x| x.as_f64())
        .ok_or_else(|| format!("missing numeric field `{key}`"))?;
    if n < 0.0 || n.fract() != 0.0 {
        return Err(format!("field `{key}` = {n} is not a non-negative integer"));
    }
    Ok(n as u64)
}

fn str_field<'v>(v: &'v Json, key: &str) -> Result<&'v str, String> {
    v.get(key)
        .and_then(|x| x.as_str())
        .ok_or_else(|| format!("missing string field `{key}`"))
}

fn arr_field<'v>(v: &'v Json, key: &str) -> Result<&'v [Json], String> {
    v.get(key)
        .and_then(|x| x.as_arr())
        .ok_or_else(|| format!("missing array field `{key}`"))
}

fn str_arr_field(v: &Json, key: &str) -> Result<Vec<String>, String> {
    arr_field(v, key)?
        .iter()
        .map(|x| {
            x.as_str()
                .map(|s| s.to_string())
                .ok_or_else(|| format!("non-string entry in `{key}`"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, SchedKind};
    use crate::expt::sweep::SweepWorkload;
    use crate::sim::EngineOptions;
    use crate::workload::WorkloadMix;

    fn tiny_grid(seeds: Vec<u64>) -> SweepGrid {
        let mut base = ExperimentConfig::default();
        base.cluster.nodes = 2;
        base.cluster.slots_per_node = 4;
        SweepGrid {
            base,
            seeds,
            scheds: vec![SchedKind::Fifo, SchedKind::Dress],
            workloads: vec![SweepWorkload::Generate {
                n: 4,
                mix: WorkloadMix::Mixed,
                small_frac: 0.3,
                arrival_ms: 2_000,
            }],
            opts: EngineOptions::default(),
        }
    }

    #[test]
    fn shard_spec_parse_and_ownership() {
        let s = ShardSpec::parse("1/3").unwrap();
        assert_eq!(s, ShardSpec { index: 1, count: 3 });
        assert!(s.owns(1) && s.owns(4) && !s.owns(0) && !s.owns(2));
        assert_eq!(s.indices(7), vec![1, 4]);
        assert_eq!(ShardSpec::full().indices(3), vec![0, 1, 2]);
        for bad in ["3", "a/3", "1/0", "3/3", "4/3"] {
            assert!(ShardSpec::parse(bad).is_err(), "{bad} accepted");
        }
    }

    #[test]
    fn fingerprint_is_stable_and_sensitive() {
        let g = tiny_grid(vec![1, 2]);
        let fp = grid_fingerprint(&g);
        assert_eq!(fp.len(), 16);
        assert_eq!(fp, grid_fingerprint(&g.clone()), "fingerprint not deterministic");
        let mut other = tiny_grid(vec![1, 2]);
        other.seeds = vec![1, 3];
        assert_ne!(fp, grid_fingerprint(&other), "seed change must change fingerprint");
        let mut opts = tiny_grid(vec![1, 2]);
        opts.opts = EngineOptions::throughput();
        assert_ne!(fp, grid_fingerprint(&opts), "sink change must change fingerprint");
    }

    #[test]
    fn meta_index_point_roundtrip() {
        let meta = SweepMeta::of(&tiny_grid(vec![1, 2, 3]), SweepMode::Grid);
        assert_eq!(meta.cells(), 6);
        for idx in 0..meta.cells() {
            let (w, k, s) = meta.point(idx);
            assert_eq!(meta.index(w, k, s), idx);
        }
        assert_eq!(meta.scheds, vec!["fifo", "dress"]);
    }

    #[test]
    fn shard_file_roundtrips_through_json() {
        let g = tiny_grid(vec![5, 6]);
        let meta = SweepMeta::of(&g, SweepMode::Grid);
        let spec = ShardSpec { index: 0, count: 2 };
        let cells = run_shard(&g, spec, 1);
        assert_eq!(cells.len(), 2, "shard 0/2 owns cells 0 and 2");
        let text = shard_to_json(&meta, spec, &cells).render();
        let back = shard_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.meta, meta);
        assert_eq!(back.shard, spec);
        assert_eq!(back.cells, cells, "JSON round-trip must be lossless");
    }

    #[test]
    fn shard_from_json_rejects_malformed_files() {
        let g = tiny_grid(vec![5, 6]);
        let meta = SweepMeta::of(&g, SweepMode::Grid);
        let spec = ShardSpec { index: 0, count: 2 };
        let cells = run_shard(&g, spec, 1);

        assert!(shard_from_json(&Json::parse("{\"format\": \"nope\"}").unwrap())
            .unwrap_err()
            .contains("not a sweep shard"));

        let mut wrong_version = shard_to_json(&meta, spec, &cells);
        wrong_version.set("version", Json::Num(99.0));
        assert!(shard_from_json(&wrong_version).unwrap_err().contains("version"));

        // A cell that the shard does not own.
        let other = run_shard(&g, ShardSpec { index: 1, count: 2 }, 1);
        let stolen = shard_to_json(&meta, spec, &other);
        assert!(shard_from_json(&stolen).unwrap_err().contains("owned indices"));
    }

    #[test]
    fn merge_validates_partition_and_fingerprints() {
        let g = tiny_grid(vec![5, 6]);
        let meta = SweepMeta::of(&g, SweepMode::Grid);
        let mk = |i: usize, n: usize| {
            let spec = ShardSpec { index: i, count: n };
            ShardFile { meta: meta.clone(), shard: spec, cells: run_shard(&g, spec, 1) }
        };

        assert!(merge_shards(vec![]).unwrap_err().contains("no shard files"));
        assert!(merge_shards(vec![mk(0, 2)]).unwrap_err().contains("missing shards [1]"));
        assert!(merge_shards(vec![mk(0, 2), mk(0, 2)]).unwrap_err().contains("duplicate"));
        assert!(merge_shards(vec![mk(0, 2), mk(1, 3)])
            .unwrap_err()
            .contains("partition width"));

        let mut alien = mk(1, 2);
        alien.meta.fingerprint = "0000000000000000".into();
        assert!(merge_shards(vec![mk(0, 2), alien]).unwrap_err().contains("mismatch"));

        // Order independence: shards merge regardless of argument order.
        let (m, cells) = merge_shards(vec![mk(1, 2), mk(0, 2)]).unwrap();
        assert_eq!(m, meta);
        let indices: Vec<usize> = cells.iter().map(|c| c.index).collect();
        assert_eq!(indices, vec![0, 1, 2, 3]);
    }

    #[test]
    fn report_renders_tables_and_aggregates() {
        let g = tiny_grid(vec![5, 6, 7]);
        let meta = SweepMeta::of(&g, SweepMode::Grid);
        let cells = run_shard(&g, ShardSpec::full(), 2);
        let report = render_sweep_report(&meta, &cells);
        assert!(report.contains("grid fingerprint"));
        assert!(report.contains("n_seeds") && report.contains("ci_lo"));
        assert!(report.contains("w0/fifo") && report.contains("w0/dress"));
        assert!(report.contains("Util (%)") && report.contains("util_pct"));
        assert!(report.contains("Goodput") && report.contains("goodput"));
        assert!(!report.contains("paper claims"), "grid mode has no claim section");
        let rows = sweep_stat_rows(&meta, &cells);
        assert_eq!(rows.len(), 8, "2 scheds x 4 metrics");
        assert!(rows.iter().all(|r| r.ci.n == 3));
    }

    #[test]
    fn partial_merge_reports_coverage_over_surviving_cells() {
        // Grid: 1 workload x [fifo, dress] x seeds [5, 6] = 4 cells.
        // Shards of /3 own {0,3}, {1}, {2}; drop shard 1 (cell 1 =
        // w0/fifo/seed 6) and merge the survivors.
        let g = tiny_grid(vec![5, 6]);
        let meta = SweepMeta::of(&g, SweepMode::Grid);
        let mk = |i: usize, n: usize| {
            let spec = ShardSpec { index: i, count: n };
            ShardFile { meta: meta.clone(), shard: spec, cells: run_shard(&g, spec, 1) }
        };
        let (m, cells, cov) = merge_shards_partial(vec![mk(2, 3), mk(0, 3)]).unwrap();
        assert_eq!(m, meta);
        assert_eq!(cov.shard_count, 3);
        assert_eq!(cov.shards_present, vec![0, 2]);
        assert_eq!(cov.shards_missing, vec![1]);
        assert_eq!(cov.missing_cells, vec![1]);
        assert_eq!(cov.present_cells(), 3);
        assert!(!cov.is_complete());
        let indices: Vec<usize> = cells.iter().map(|c| c.index).collect();
        assert_eq!(indices, vec![0, 2, 3]);

        let report = render_partial_sweep_report(&meta, &cells, &cov);
        assert!(report.contains("coverage: 2/3 shards present, 3/4 cells"), "{report}");
        assert!(report.contains("shards missing: [1]"));
        assert!(report.contains("cell 1 = w0/fifo/seed 6"));
        // Degraded aggregates: fifo survives with one seed, dress with two.
        let rows = sweep_stat_rows(&meta, &cells);
        let n_of = |g: &str| rows.iter().find(|r| r.group == g).unwrap().ci.n;
        assert_eq!(n_of("w0/fifo"), 1);
        assert_eq!(n_of("w0/dress"), 2);

        // A complete set through the partial path covers everything.
        let (_, cells2, cov2) = merge_shards_partial(vec![mk(0, 2), mk(1, 2)]).unwrap();
        assert!(cov2.is_complete());
        assert_eq!(cells2.len(), 4);

        // The partial path still rejects foreign grids.
        let mut alien = mk(1, 3);
        alien.meta.fingerprint = "0000000000000000".into();
        assert!(merge_shards_partial(vec![mk(0, 3), alien]).unwrap_err().contains("mismatch"));
    }

    #[test]
    fn cell_summary_validates_fault_integers() {
        let g = tiny_grid(vec![5]);
        let (cfg, specs) = g.cell(0);
        let r = crate::sim::run_experiment_with(&cfg, specs, g.opts);
        let cell = CellSummary::of(&g, 0, &r);
        assert_eq!(cell.outages, 0, "no fault plan, no outages");
        assert_eq!(cell.lost_attempts, 0);
        assert!((cell.goodput() - r.goodput()).abs() < 1e-12);
        let mut bad = cell.to_json();
        bad.set("lost_attempts", Json::Num((cell.attempts + 1) as f64));
        assert!(CellSummary::from_json(&bad).unwrap_err().contains("lost_attempts"));
        let mut bad = cell.to_json();
        bad.set("lost_work_ms", Json::Num(cell.wasted_work_ms as f64 + 1.0));
        assert!(CellSummary::from_json(&bad).unwrap_err().contains("lost_work_ms"));
    }

    #[test]
    fn cell_summary_carries_federation_integers() {
        // A federated grid cell rides the same wire format: the per-run
        // federation counters survive the JSON round-trip exactly, and
        // impossible combinations are rejected.
        let mut g = tiny_grid(vec![5]);
        g.base.federation.cells = 2;
        let (cfg, specs) = g.cell(0);
        let r = crate::sim::run_experiment_with(&cfg, specs, g.opts);
        assert_eq!(r.cells, 2);
        let cell = CellSummary::of(&g, 0, &r);
        assert_eq!(cell.fed_cells, 2);
        assert_eq!(cell.migrations, r.migrations);
        assert_eq!(cell.imbalance_max_milli, (r.imbalance_max * 1000.0).round() as u64);
        let back = CellSummary::from_json(&cell.to_json()).unwrap();
        assert_eq!(back, cell, "federation integers must round-trip exactly");

        let mut bad = cell.to_json();
        bad.set("fed_cells", Json::Num(0.0));
        assert!(CellSummary::from_json(&bad).unwrap_err().contains("fed_cells"));
        let mut bad = cell.to_json();
        bad.set("fed_cells", Json::Num(1.0));
        bad.set("migrations", Json::Num(3.0));
        assert!(CellSummary::from_json(&bad)
            .unwrap_err()
            .contains("federation counters"));
    }

    #[test]
    fn cell_summary_carries_exact_utilization_integers() {
        // The wire format carries the integral's integer terms, not the
        // derived fraction — a reassembled summary divides the same
        // integers the originating run divided (exact, no tolerance).
        let g = tiny_grid(vec![5]);
        let (cfg, specs) = g.cell(1); // dress cell
        let r = crate::sim::run_experiment_with(&cfg, specs, g.opts);
        let cell = CellSummary::of(&g, 1, &r);
        assert_eq!(cell.total_containers, 8);
        assert!(cell.util_samples > 0 && cell.util_span_ms > 0);
        assert!(cell.util_peak <= cell.total_containers);
        assert_eq!(cell.util(), crate::metrics::UtilSummary::from_parts(
            r.util.total, r.util.samples, r.util.span_ms, r.util.area_ms,
            r.util.sum_used, r.util.peak_used,
        ));
        assert_eq!(
            cell.util().mean_utilization().to_bits(),
            r.system.mean_utilization.to_bits(),
            "wire roundtrip must preserve the utilization bit-for-bit"
        );
        // Validation rejects impossible occupancy integers.
        let mut bad = cell.to_json();
        bad.set("util_peak", Json::Num((cell.total_containers + 1) as f64));
        assert!(CellSummary::from_json(&bad).unwrap_err().contains("util_peak"));
        let mut bad = cell.to_json();
        bad.set("util_area_ms", Json::Num(1e15));
        assert!(CellSummary::from_json(&bad).unwrap_err().contains("capacity"));
    }
}
