//! HiBench benchmark profiles (paper §V.A.2): the ten benchmarks across
//! five categories, parameterized so the generated jobs reproduce the
//! paper's trace shapes (Fig. 2 WordCount 20 map / 4 reduce; Fig. 3
//! PageRank-MR 4 phases with a heading task; Fig. 4 PageRank-Spark with a
//! trailing task).
//!
//! Durations are *profiles*, not measurements: each benchmark defines its
//! phase structure, nominal per-task lengths, and data sensitivity; actual
//! task durations are sampled per job (scale factor + jitter + heading /
//! trailing effects).

use super::dataset::Dataset;
use super::skew::zipf_partition_weights;
use crate::jobs::{JobId, JobSpec, PhaseKind, PhaseSpec, Platform, TaskSpec};
use crate::util::rng::Rng;
use crate::util::Time;

/// The ten HiBench benchmarks used in the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Benchmark {
    WordCount,
    Sort,
    TeraSort,
    KMeans,
    LogisticRegression,
    Bayes,
    Scan,
    Join,
    PageRank,
    NWeight,
}

impl Benchmark {
    pub const ALL: [Benchmark; 10] = [
        Benchmark::WordCount,
        Benchmark::Sort,
        Benchmark::TeraSort,
        Benchmark::KMeans,
        Benchmark::LogisticRegression,
        Benchmark::Bayes,
        Benchmark::Scan,
        Benchmark::Join,
        Benchmark::PageRank,
        Benchmark::NWeight,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Benchmark::WordCount => "wordcount",
            Benchmark::Sort => "sort",
            Benchmark::TeraSort => "terasort",
            Benchmark::KMeans => "kmeans",
            Benchmark::LogisticRegression => "lr",
            Benchmark::Bayes => "bayes",
            Benchmark::Scan => "scan",
            Benchmark::Join => "join",
            Benchmark::PageRank => "pagerank",
            Benchmark::NWeight => "nweight",
        }
    }

    /// Benchmarks runnable on each platform (paper: MR runs 1-10, Spark
    /// runs 4-6 and 9-10).
    pub fn supports(&self, platform: Platform) -> bool {
        match platform {
            Platform::MapReduce => true,
            Platform::Spark => matches!(
                self,
                Benchmark::KMeans
                    | Benchmark::LogisticRegression
                    | Benchmark::Bayes
                    | Benchmark::PageRank
                    | Benchmark::NWeight
            ),
        }
    }

    /// Is this a small-demand benchmark flavor? (Scan/Join Hive queries and
    /// small WordCounts are the paper's typical SD jobs.)
    pub fn naturally_small(&self) -> bool {
        matches!(self, Benchmark::Scan | Benchmark::Join)
    }
}

pub fn benchmark_names() -> Vec<&'static str> {
    Benchmark::ALL.iter().map(|b| b.name()).collect()
}

/// Profile internals: phase templates per benchmark & platform.
struct Profile {
    /// (kind, task-count base, nominal task ms) per phase; task count
    /// scales with the job's size factor.
    phases: Vec<(PhaseKind, u32, Time)>,
    /// Spark partition skew (0 for MR; drives trailing tasks).
    skew: f64,
    /// Dataset chunks (MB) for MR map phases (drives heading tasks).
    chunks_mb: Vec<u64>,
}

fn profile(b: Benchmark, platform: Platform, small: bool) -> Profile {
    use Benchmark::*;
    use PhaseKind::*;
    // (task base counts, nominal durations in ms) tuned so a 20-job run on
    // a 40-container cluster is congested with a makespan around 10^3 s,
    // matching the paper's scale.
    let p = match (b, platform) {
        (WordCount, Platform::MapReduce) => Profile {
            phases: vec![(Map, 20, 28_000), (Reduce, 4, 16_000)],
            skew: 0.0,
            chunks_mb: vec![1_664, 1_280],
        },
        (Sort, Platform::MapReduce) => Profile {
            phases: vec![(Map, 12, 18_000), (Reduce, 8, 34_000)],
            skew: 0.0,
            chunks_mb: vec![2_048, 1_536],
        },
        (TeraSort, Platform::MapReduce) => Profile {
            phases: vec![(Map, 24, 30_000), (Reduce, 12, 42_000)],
            skew: 0.0,
            chunks_mb: vec![4_096, 2_048, 1_664],
        },
        (KMeans, Platform::MapReduce) => Profile {
            phases: vec![(Map, 12, 26_000), (Reduce, 4, 14_000), (Map, 12, 24_000), (Reduce, 4, 13_000)],
            skew: 0.0,
            chunks_mb: vec![1_536, 1_024],
        },
        (KMeans, Platform::Spark) => Profile {
            phases: vec![(SparkStage, 14, 22_000), (SparkStage, 14, 19_000), (SparkStage, 6, 12_000)],
            skew: 0.5,
            chunks_mb: vec![],
        },
        (LogisticRegression, Platform::MapReduce) => Profile {
            phases: vec![(Map, 10, 24_000), (Reduce, 4, 15_000)],
            skew: 0.0,
            chunks_mb: vec![1_280, 768],
        },
        (LogisticRegression, Platform::Spark) => Profile {
            phases: vec![(SparkStage, 12, 20_000), (SparkStage, 12, 18_000), (SparkStage, 4, 9_000)],
            skew: 0.45,
            chunks_mb: vec![],
        },
        (Bayes, Platform::MapReduce) => Profile {
            phases: vec![(Map, 14, 26_000), (Reduce, 6, 18_000)],
            skew: 0.0,
            chunks_mb: vec![1_792, 1_024],
        },
        (Bayes, Platform::Spark) => Profile {
            phases: vec![(SparkStage, 12, 21_000), (SparkStage, 8, 16_000)],
            skew: 0.55,
            chunks_mb: vec![],
        },
        (Scan, _) => Profile {
            phases: vec![(Map, 3, 14_000)],
            skew: 0.0,
            chunks_mb: vec![640],
        },
        (Join, _) => Profile {
            phases: vec![(Map, 3, 16_000), (Reduce, 1, 11_000)],
            skew: 0.0,
            chunks_mb: vec![512, 256],
        },
        // Fig 3: PageRank MR = two stages x (map + reduce) = 4 phases,
        // reduce-1 has 9 tasks with one heading task.
        (PageRank, Platform::MapReduce) => Profile {
            phases: vec![(Map, 16, 24_000), (Reduce, 9, 18_250), (Map, 14, 21_000), (Reduce, 8, 16_000)],
            skew: 0.0,
            chunks_mb: vec![2_048, 1_664],
        },
        (PageRank, Platform::Spark) => Profile {
            phases: vec![(SparkStage, 16, 12_800), (SparkStage, 12, 11_000), (SparkStage, 8, 9_000)],
            skew: 0.65, // Fig 4 trailing task
            chunks_mb: vec![],
        },
        (NWeight, Platform::Spark) => Profile {
            phases: vec![(SparkStage, 16, 26_000), (SparkStage, 16, 24_000), (SparkStage, 10, 18_000), (SparkStage, 6, 12_000)],
            skew: 0.6,
            chunks_mb: vec![],
        },
        (NWeight, Platform::MapReduce) => Profile {
            phases: vec![(Map, 16, 28_000), (Reduce, 8, 20_000), (Map, 12, 22_000), (Reduce, 6, 15_000)],
            skew: 0.0,
            chunks_mb: vec![2_560, 1_536],
        },
        (b, p) => unreachable!("unsupported benchmark/platform combo {b:?}/{p} (guarded by supports())"),
    };
    if small {
        // Small-demand variant: tiny dataset — few tasks, shorter phases.
        Profile {
            phases: p
                .phases
                .iter()
                .map(|&(k, n, d)| (k, (n / 4).max(1), d / 2))
                .collect(),
            skew: p.skew,
            chunks_mb: p.chunks_mb.iter().map(|c| (c / 4).max(128)).collect(),
        }
    } else {
        p
    }
}

/// Materialize one job from a benchmark profile.
///
/// `size_factor` scales task counts (0.5 .. 1.5 typical); task durations
/// get per-task jitter plus heading (MR map phases, from the dataset block
/// layout) and trailing (Spark stages, from zipf skew) effects.
pub fn build_job(
    id: JobId,
    b: Benchmark,
    platform: Platform,
    small: bool,
    submit_ms: Time,
    size_factor: f64,
    rng: &mut Rng,
) -> JobSpec {
    assert!(b.supports(platform), "{b:?} not runnable on {platform}");
    let prof = profile(b, platform, small);
    let mut phases = Vec::new();
    for (pi, &(kind, base_n, base_ms)) in prof.phases.iter().enumerate() {
        let mut n = ((base_n as f64 * size_factor).round() as u32).max(1);
        let mut multipliers: Vec<f64>;
        if kind == PhaseKind::Map && !prof.chunks_mb.is_empty() {
            // Heading tasks from block arithmetic: derive the task count
            // from the dataset layout scaled to n blocks.
            let ds = Dataset::new(
                prof.chunks_mb
                    .iter()
                    .map(|&c| ((c as f64 * size_factor) as u64).max(128))
                    .collect(),
                512,
            );
            multipliers = ds.task_multipliers();
            // Resize to ~n tasks by tiling full blocks (keeps the
            // underloaded final blocks).
            while (multipliers.len() as u32) < n {
                multipliers.insert(0, 1.0);
            }
            n = multipliers.len() as u32;
        } else if kind == PhaseKind::SparkStage && prof.skew > 0.0 {
            multipliers = zipf_partition_weights(rng, n as usize, prof.skew);
        } else {
            multipliers = vec![1.0; n as usize];
        }
        let durations: Vec<Time> = multipliers
            .iter()
            .map(|&m| {
                // ±8% execution jitter on top of the data-size multiplier.
                let jitter = rng.range_f64(0.92, 1.08);
                ((base_ms as f64 * m * jitter) as Time).max(500)
            })
            .collect();
        let _ = pi;
        phases.push(PhaseSpec {
            kind,
            tasks: durations.iter().map(|&d| TaskSpec { duration_ms: d }).collect(),
        });
    }
    // Demand r_i: what the job asks the RM for — its widest phase, capped
    // for small jobs at a genuinely small request.
    let width = phases.iter().map(|p| p.tasks.len() as u32).max().unwrap_or(1);
    let demand = if small { width.min(4).max(1) } else { width };
    JobSpec {
        id,
        name: format!("{}-{}", b.name(), if small { "small" } else { "full" }),
        platform,
        submit_ms,
        demand: crate::jobs::Demand::scalar(demand),
        phases,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_benchmarks_build_valid_mr_jobs() {
        let mut rng = Rng::new(1);
        for (i, b) in Benchmark::ALL.iter().enumerate() {
            let j = build_job(i as u32 + 1, *b, Platform::MapReduce, false, 0, 1.0, &mut rng);
            j.validate().unwrap();
            assert!(j.demand.cpu >= 1);
        }
    }

    #[test]
    fn spark_subset_builds() {
        let mut rng = Rng::new(2);
        for b in Benchmark::ALL.iter().filter(|b| b.supports(Platform::Spark)) {
            let j = build_job(1, *b, Platform::Spark, false, 0, 1.0, &mut rng);
            j.validate().unwrap();
            assert!(j.phases.iter().all(|p| p.kind == PhaseKind::SparkStage));
        }
    }

    #[test]
    #[should_panic(expected = "not runnable")]
    fn wordcount_not_on_spark() {
        let mut rng = Rng::new(3);
        build_job(1, Benchmark::WordCount, Platform::Spark, false, 0, 1.0, &mut rng);
    }

    #[test]
    fn wordcount_matches_fig2_shape() {
        let mut rng = Rng::new(4);
        let j = build_job(1, Benchmark::WordCount, Platform::MapReduce, false, 0, 1.0, &mut rng);
        assert_eq!(j.phases.len(), 2);
        assert_eq!(j.phases[1].tasks.len(), 4, "4 reduce tasks");
        assert!(j.phases[0].tasks.len() >= 20, "~20 map tasks");
    }

    #[test]
    fn pagerank_mr_has_heading_task() {
        let mut rng = Rng::new(5);
        let j = build_job(1, Benchmark::PageRank, Platform::MapReduce, false, 0, 1.0, &mut rng);
        assert_eq!(j.phases.len(), 4, "two MR stages = 4 phases");
        // Map phases contain underloaded block tasks (heading).
        let map_durs: Vec<Time> = j.phases[0].tasks.iter().map(|t| t.duration_ms).collect();
        let max = *map_durs.iter().max().unwrap() as f64;
        let min = *map_durs.iter().min().unwrap() as f64;
        assert!(min < 0.8 * max, "heading task expected: {map_durs:?}");
    }

    #[test]
    fn pagerank_spark_has_trailing_task() {
        let mut rng = Rng::new(6);
        let j = build_job(1, Benchmark::PageRank, Platform::Spark, false, 0, 1.0, &mut rng);
        let durs: Vec<Time> = j.phases[0].tasks.iter().map(|t| t.duration_ms).collect();
        let mut sorted = durs.clone();
        sorted.sort_unstable();
        let max = sorted[sorted.len() - 1] as f64;
        let second = sorted[sorted.len() - 2] as f64;
        assert!(max > second * 1.05, "trailing task expected: {durs:?}");
    }

    #[test]
    fn small_variant_has_small_demand() {
        let mut rng = Rng::new(7);
        let j = build_job(1, Benchmark::Scan, Platform::MapReduce, true, 0, 1.0, &mut rng);
        assert!(j.demand.cpu <= 4, "small job demand {} > 4", j.demand);
        let big = build_job(2, Benchmark::TeraSort, Platform::MapReduce, false, 0, 1.0, &mut rng);
        assert!(big.demand.cpu > 10);
    }

    #[test]
    fn size_factor_scales_tasks() {
        let mut rng = Rng::new(8);
        let s = build_job(1, Benchmark::Sort, Platform::MapReduce, false, 0, 0.5, &mut rng);
        let l = build_job(2, Benchmark::Sort, Platform::MapReduce, false, 0, 1.5, &mut rng);
        assert!(l.total_tasks() > s.total_tasks());
    }

    #[test]
    fn deterministic_per_seed() {
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let a = build_job(1, Benchmark::Bayes, Platform::Spark, false, 0, 1.0, &mut r1);
        let b = build_job(1, Benchmark::Bayes, Platform::Spark, false, 0, 1.0, &mut r2);
        assert_eq!(a, b);
    }
}
