//! Data-skew model for Spark stages — the mechanism behind trailing tasks
//! (paper §III.A.3): partition sizes follow a Zipf-like distribution, so a
//! few tasks process far more data and run correspondingly longer (the
//! paper's Fig. 4 trailing task runs +38% over the second longest).

use crate::util::rng::{Rng, ZipfSampler};

/// Partition weight multipliers for `n` tasks: mean ~1.0, with a heavy
/// right tail controlled by `skew` (0 = uniform; paper-like behavior at
/// ~0.4-0.8).  Deterministic per `rng` stream.
pub fn zipf_partition_weights(rng: &mut Rng, n: usize, skew: f64) -> Vec<f64> {
    if n == 0 {
        return Vec::new();
    }
    if skew <= 0.0 {
        return vec![1.0; n];
    }
    // Draw ranks from a zipf law, then normalize to mean 1.0.  The weight
    // table is built once for all n draws (it was rebuilt per draw).
    let zipf = ZipfSampler::new(n.max(2), 1.0 + skew);
    let raw: Vec<f64> = (0..n)
        .map(|_| {
            let rank = zipf.draw(rng) as f64;
            // weight inversely related to rank: rank 1 = heaviest partition
            1.0 / rank.powf(0.5)
        })
        .collect();
    let mean: f64 = raw.iter().sum::<f64>() / n as f64;
    // Invert: most draws land on rank 1 (weight 1.0); rare high ranks are
    // light. To get a heavy *tail* instead, reciprocate around the mean.
    let weights: Vec<f64> = raw.iter().map(|w| (mean / w).max(0.25)).collect();
    let m2: f64 = weights.iter().sum::<f64>() / n as f64;
    weights.iter().map(|w| w / m2).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_when_no_skew() {
        let mut rng = Rng::new(1);
        let w = zipf_partition_weights(&mut rng, 8, 0.0);
        assert_eq!(w, vec![1.0; 8]);
    }

    #[test]
    fn mean_stays_near_one() {
        let mut rng = Rng::new(2);
        let w = zipf_partition_weights(&mut rng, 64, 0.6);
        let mean: f64 = w.iter().sum::<f64>() / w.len() as f64;
        assert!((mean - 1.0).abs() < 1e-9, "mean {mean}");
    }

    #[test]
    fn skew_produces_trailing_tasks() {
        let mut rng = Rng::new(3);
        let w = zipf_partition_weights(&mut rng, 32, 0.8);
        let max = w.iter().copied().fold(0.0_f64, f64::max);
        let mut sorted = w.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let second = sorted[sorted.len() - 2];
        // At least one partition clearly dominates (paper: +38%).
        assert!(max / second > 1.05, "max {max} second {second}");
    }

    #[test]
    fn empty_and_degenerate() {
        let mut rng = Rng::new(4);
        assert!(zipf_partition_weights(&mut rng, 0, 0.5).is_empty());
        let one = zipf_partition_weights(&mut rng, 1, 0.5);
        assert_eq!(one.len(), 1);
        assert!((one[0] - 1.0).abs() < 1e-9);
    }
}
