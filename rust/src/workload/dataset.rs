//! Dataset chunk / block / split arithmetic — the mechanism behind heading
//! tasks (paper §III.A.2 and Fig. 5): a dataset is stored in chunks, each
//! chunk split into fixed-size blocks; the final block of each chunk is
//! usually underloaded, so the task processing it finishes abnormally fast.

/// A dataset as a list of chunk sizes (MB).
#[derive(Debug, Clone, PartialEq)]
pub struct Dataset {
    pub chunks_mb: Vec<u64>,
    /// Block size == map split size (paper uses 512 MB).
    pub block_mb: u64,
}

impl Dataset {
    pub fn new(chunks_mb: Vec<u64>, block_mb: u64) -> Self {
        assert!(block_mb > 0);
        Dataset { chunks_mb, block_mb }
    }

    /// The paper's Fig. 5 example: 1,664 MB + 1,280 MB at 512 MB splits.
    pub fn paper_fig5() -> Self {
        Dataset::new(vec![1_664, 1_280], 512)
    }

    /// Per-block payload sizes in MB, chunk by chunk. One map task per block.
    pub fn block_sizes_mb(&self) -> Vec<u64> {
        let mut blocks = Vec::new();
        for &chunk in &self.chunks_mb {
            let full = chunk / self.block_mb;
            for _ in 0..full {
                blocks.push(self.block_mb);
            }
            let rem = chunk % self.block_mb;
            if rem > 0 {
                blocks.push(rem);
            }
        }
        blocks
    }

    /// Map-task duration multipliers: processing time scales with payload,
    /// so underloaded final blocks yield heading tasks (<1.0 multipliers).
    pub fn task_multipliers(&self) -> Vec<f64> {
        self.block_sizes_mb()
            .iter()
            .map(|&b| b as f64 / self.block_mb as f64)
            .collect()
    }

    pub fn n_tasks(&self) -> usize {
        self.block_sizes_mb().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fig5_block_layout() {
        // Data A: 1664 = 3*512 + 128; Data B: 1280 = 2*512 + 256.
        let d = Dataset::paper_fig5();
        assert_eq!(
            d.block_sizes_mb(),
            vec![512, 512, 512, 128, 512, 512, 256]
        );
        assert_eq!(d.n_tasks(), 7);
    }

    #[test]
    fn multipliers_flag_heading_tasks() {
        let d = Dataset::paper_fig5();
        let m = d.task_multipliers();
        // Heading tasks: 128/512 = 0.25 and 256/512 = 0.5.
        assert!((m[3] - 0.25).abs() < 1e-12);
        assert!((m[6] - 0.5).abs() < 1e-12);
        assert_eq!(m.iter().filter(|&&x| x < 1.0).count(), 2);
    }

    #[test]
    fn exact_fit_has_no_heading_task() {
        let d = Dataset::new(vec![1_024], 512);
        assert_eq!(d.block_sizes_mb(), vec![512, 512]);
        assert!(d.task_multipliers().iter().all(|&m| m == 1.0));
    }

    #[test]
    fn tiny_chunk_single_block() {
        let d = Dataset::new(vec![100], 512);
        assert_eq!(d.block_sizes_mb(), vec![100]);
    }
}
