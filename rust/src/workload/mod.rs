//! HiBench-fidelity workload model (paper §V.A.2): ten benchmark profiles
//! across MapReduce and Spark-on-YARN, with the three task-execution
//! characteristics of §III.A built in:
//!
//! * starting-time variation — emerges from the simulator's multi-round
//!   allocation + container transition delays (not synthesized here);
//! * heading tasks — from dataset chunk/block/split arithmetic
//!   ([`dataset`]): the last block of each chunk is underloaded;
//! * trailing tasks — from Zipf partition skew on Spark stages ([`skew`]).

pub mod dataset;
pub mod generator;
pub mod hibench;
pub mod skew;
pub mod tracefile;

pub use dataset::Dataset;
pub use generator::{congested_burst, generate, motivating_example, WorkloadMix};
pub use hibench::{benchmark_names, build_job, Benchmark};
pub use skew::zipf_partition_weights;
pub use tracefile::{from_trace, to_trace};
