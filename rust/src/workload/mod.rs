//! HiBench-fidelity workload model (paper §V.A.2): ten benchmark profiles
//! across MapReduce and Spark-on-YARN, with the three task-execution
//! characteristics of §III.A built in:
//!
//! * starting-time variation — emerges from the simulator's multi-round
//!   allocation + container transition delays (not synthesized here);
//! * heading tasks — from dataset chunk/block/split arithmetic
//!   ([`dataset`]): the last block of each chunk is underloaded;
//! * trailing tasks — from Zipf partition skew on Spark stages ([`skew`]).

pub mod dataset;
pub mod generator;
pub mod hibench;
pub mod skew;
pub mod tracefile;

pub use dataset::Dataset;
pub use generator::{
    congested_burst, congested_burst_vec, congested_burst_vec_jitter, generate,
    motivating_example, WorkloadMix,
};
pub use hibench::{benchmark_names, build_job, Benchmark};
pub use skew::zipf_partition_weights;
pub use tracefile::{from_trace, to_trace};

use crate::jobs::JobSpec;
use crate::util::Time;

/// One workload axis point of a sweep; `build(seed)` materializes the
/// spec list.  This is the unified source type behind `dress run`,
/// `dress sweep`, and the shard runner — synthetic presets and recorded
/// traces flow through the same grid machinery.
///
/// The `Debug` form of a `WorkloadSource` feeds the sweep grid
/// fingerprint (`expt::shard::grid_fingerprint`), so a [`Self::Trace`]
/// carries its full text: shards of different traces — or of a trace vs
/// a synthetic preset — refuse to merge.
#[derive(Debug, Clone)]
pub enum WorkloadSource {
    /// [`generate`] — the paper's HiBench mixes.
    Generate { n: u32, mix: WorkloadMix, small_frac: f64, arrival_ms: Time },
    /// [`congested_burst`] — heavy-tailed demands, Poisson burst.
    CongestedBurst { n: u32, arrival_mean_ms: u64 },
    /// [`congested_burst_vec`] — the burst preset with stochastic
    /// *vector* (cpu × mem) demand draws on an isolated RNG stream.
    CongestedBurstVec { n: u32, arrival_mean_ms: u64 },
    /// [`congested_burst_vec_jitter`] — the vector preset plus per-task
    /// memory jitter (own preset so `burst-vec` goldens stay bit-stable).
    CongestedBurstVecJitter { n: u32, arrival_mean_ms: u64 },
    /// A recorded trace ([`tracefile`]): seed-independent job specs.
    /// `label` is the display name (usually the file path); `text` is the
    /// full trace body, validated at construction by [`Self::trace`].
    Trace { label: String, text: String },
}

impl WorkloadSource {
    /// Build a trace-backed source, validating the text up front so
    /// [`Self::build`] cannot fail later.
    pub fn trace(label: impl Into<String>, text: impl Into<String>) -> Result<Self, String> {
        let label = label.into();
        let text = text.into();
        from_trace(&text).map_err(|e| format!("trace {label}: {e}"))?;
        Ok(WorkloadSource::Trace { label, text })
    }

    /// Short display name for reports and sweep progress lines.
    pub fn label(&self) -> String {
        match self {
            WorkloadSource::Generate { n, mix, .. } => format!("generate-{n}-{mix:?}"),
            WorkloadSource::CongestedBurst { n, .. } => format!("burst-{n}"),
            WorkloadSource::CongestedBurstVec { n, .. } => format!("burst-vec-{n}"),
            WorkloadSource::CongestedBurstVecJitter { n, .. } => {
                format!("burst-vec-jitter-{n}")
            }
            WorkloadSource::Trace { label, .. } => label.clone(),
        }
    }

    /// Materialize the spec list for one seed.  Traces are
    /// seed-independent: every cell replays the recorded jobs verbatim
    /// (engine delay sampling still varies with the configured seed).
    pub fn build(&self, seed: u64) -> Vec<JobSpec> {
        match self {
            WorkloadSource::Generate { n, mix, small_frac, arrival_ms } => {
                generate(*n, *mix, *small_frac, *arrival_ms, seed)
            }
            WorkloadSource::CongestedBurst { n, arrival_mean_ms } => {
                congested_burst(*n, *arrival_mean_ms, seed)
            }
            WorkloadSource::CongestedBurstVec { n, arrival_mean_ms } => {
                congested_burst_vec(*n, *arrival_mean_ms, seed)
            }
            WorkloadSource::CongestedBurstVecJitter { n, arrival_mean_ms } => {
                congested_burst_vec_jitter(*n, *arrival_mean_ms, seed)
            }
            WorkloadSource::Trace { label: _, text } => {
                from_trace(text).expect("trace validated by WorkloadSource::trace")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_source_validates_up_front_and_ignores_seed() {
        let text = "job 1 a mapreduce 0 2 phases map:1000,1000\n";
        let src = WorkloadSource::trace("t.trace", text).unwrap();
        assert_eq!(src.build(1), src.build(99), "traces must be seed-independent");
        assert_eq!(src.build(1).len(), 1);
        assert_eq!(src.label(), "t.trace");
        let err = WorkloadSource::trace("bad.trace", "job zero").unwrap_err();
        assert!(err.contains("bad.trace"), "error must name the trace: {err}");
    }

    #[test]
    fn synthetic_sources_build_their_presets() {
        let g = WorkloadSource::Generate {
            n: 4,
            mix: WorkloadMix::Mixed,
            small_frac: 0.3,
            arrival_ms: 2_000,
        };
        assert_eq!(g.build(42), generate(4, WorkloadMix::Mixed, 0.3, 2_000, 42));
        let b = WorkloadSource::CongestedBurst { n: 5, arrival_mean_ms: 100 };
        assert_eq!(b.build(42), congested_burst(5, 100, 42));
        let v = WorkloadSource::CongestedBurstVec { n: 5, arrival_mean_ms: 100 };
        assert_eq!(v.build(42), congested_burst_vec(5, 100, 42));
        assert_eq!(v.build(42).len(), 5);
        let j = WorkloadSource::CongestedBurstVecJitter { n: 5, arrival_mean_ms: 100 };
        assert_eq!(j.build(42), congested_burst_vec_jitter(5, 100, 42));
        assert_eq!(j.label(), "burst-vec-jitter-5");
    }

    #[test]
    fn trace_debug_form_is_content_addressed() {
        // The grid fingerprint hashes Debug output: two traces with equal
        // labels but different bodies must not collide.
        let a = WorkloadSource::trace("t", "job 1 a mapreduce 0 2 phases map:1,1\n").unwrap();
        let b = WorkloadSource::trace("t", "job 1 a mapreduce 0 1 phases map:9\n").unwrap();
        assert_ne!(format!("{a:?}"), format!("{b:?}"));
    }
}
