//! Workload trace files: import/export job specs as a line-oriented text
//! format, so experiments can run recorded traces (the paper's evaluation
//! methodology) rather than only generated mixes.
//!
//! Format (one job per line, `#` comments):
//!
//! ```text
//! job <id> <name> <platform> <submit_ms> <demand> phases <kind>:<ms>,<ms>... [<kind>:...]
//! job 1 wordcount mapreduce 0 4 phases map:28000,27500,7000 reduce:16000
//! job 2 fatjoin spark 4000 4x12 phases stage:9000,9000,8000,7000
//! ```
//!
//! The demand token is [`Demand`]'s display form: a bare count for
//! uniform (scalar) demands, `<cpu>x<mem>` for vector demands — so
//! traces written before the vector-demand redesign parse unchanged.

use crate::jobs::{Demand, JobSpec, PhaseKind, PhaseSpec, Platform, TaskSpec};
use crate::util::Time;

/// Trace names are single whitespace-delimited tokens on `#`-commentable
/// lines, so a name containing whitespace or `#` (perfectly legal in a
/// `JobSpec`) would render a line `from_trace` cannot re-parse — or would
/// silently truncate at the comment marker.  Rendering substitutes `_`
/// for those bytes (and for an empty name), which makes
/// parse → render → parse a fixed point for every input.
fn sanitize_name(name: &str) -> String {
    if name.is_empty() {
        return "_".into();
    }
    name.chars().map(|c| if c.is_whitespace() || c == '#' { '_' } else { c }).collect()
}

/// Serialize specs to the trace format.
pub fn to_trace(specs: &[JobSpec]) -> String {
    let mut out = String::from("# dress workload trace v1\n");
    for s in specs {
        out.push_str(&format!(
            "job {} {} {} {} {} phases",
            s.id,
            sanitize_name(&s.name),
            s.platform,
            s.submit_ms,
            s.demand
        ));
        for p in &s.phases {
            let kind = match p.kind {
                PhaseKind::Map => "map",
                PhaseKind::Reduce => "reduce",
                PhaseKind::SparkStage => "stage",
            };
            let durs: Vec<String> =
                p.tasks.iter().map(|t| t.duration_ms.to_string()).collect();
            out.push_str(&format!(" {kind}:{}", durs.join(",")));
        }
        out.push('\n');
    }
    out
}

/// Parse a trace. Errors carry 1-based line numbers.
pub fn from_trace(text: &str) -> Result<Vec<JobSpec>, String> {
    let mut specs = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let err = |m: &str| format!("line {}: {m}", ln + 1);
        let mut it = line.split_whitespace();
        if it.next() != Some("job") {
            return Err(err("expected `job`"));
        }
        let id: u32 = it
            .next()
            .ok_or_else(|| err("missing id"))?
            .parse()
            .map_err(|e| err(&format!("id: {e}")))?;
        let name = it.next().ok_or_else(|| err("missing name"))?.to_string();
        let platform = match it.next().ok_or_else(|| err("missing platform"))? {
            "mapreduce" => Platform::MapReduce,
            "spark" => Platform::Spark,
            other => return Err(err(&format!("unknown platform `{other}`"))),
        };
        let submit_ms: Time = it
            .next()
            .ok_or_else(|| err("missing submit_ms"))?
            .parse()
            .map_err(|e| err(&format!("submit_ms: {e}")))?;
        let demand = Demand::parse(it.next().ok_or_else(|| err("missing demand"))?)
            .map_err(|e| err(&e))?;
        if it.next() != Some("phases") {
            return Err(err("expected `phases`"));
        }
        let mut phases = Vec::new();
        for tok in it {
            let (kind_s, durs_s) = tok
                .split_once(':')
                .ok_or_else(|| err(&format!("bad phase token `{tok}`")))?;
            let kind = match kind_s {
                "map" => PhaseKind::Map,
                "reduce" => PhaseKind::Reduce,
                "stage" => PhaseKind::SparkStage,
                other => return Err(err(&format!("unknown phase kind `{other}`"))),
            };
            let tasks: Vec<TaskSpec> = durs_s
                .split(',')
                .map(|d| {
                    d.parse::<Time>()
                        .map(|duration_ms| TaskSpec { duration_ms })
                        .map_err(|e| err(&format!("duration `{d}`: {e}")))
                })
                .collect::<Result<_, _>>()?;
            phases.push(PhaseSpec { kind, tasks });
        }
        let spec = JobSpec { id, name, platform, submit_ms, demand, phases };
        spec.validate().map_err(|e| err(&e))?;
        specs.push(spec);
    }
    Ok(specs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate, WorkloadMix};

    #[test]
    fn roundtrip_generated_workload() {
        let specs = generate(8, WorkloadMix::Mixed, 0.3, 2_000, 42);
        let text = to_trace(&specs);
        let back = from_trace(&text).unwrap();
        assert_eq!(specs, back);
    }

    #[test]
    fn parses_hand_written_trace() {
        let specs = from_trace(
            "# comment\n\
             job 1 wordcount mapreduce 0 4 phases map:28000,27500,7000 reduce:16000\n\
             job 2 pagerank spark 5000 8 phases stage:12000,12800 stage:9000\n",
        )
        .unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].phases.len(), 2);
        assert_eq!(specs[0].phases[0].tasks.len(), 3);
        assert_eq!(specs[1].platform, Platform::Spark);
        assert_eq!(specs[1].submit_ms, 5_000);
    }

    #[test]
    fn errors_carry_line_numbers() {
        assert!(from_trace("nope").unwrap_err().contains("line 1"));
        assert!(from_trace("\njob x").unwrap_err().contains("line 2"));
        assert!(from_trace("job 1 a mapreduce 0 4 phases map:abc")
            .unwrap_err()
            .contains("duration"));
        // invalid spec (no phases) rejected via validate()
        assert!(from_trace("job 1 a mapreduce 0 4 phases").is_err());
    }

    #[test]
    fn rejects_malformed_rows() {
        // One broken field per case, each with the offending token in the
        // error so a bad row in a big trace is findable.
        for (row, needle) in [
            ("job x a mapreduce 0 4 phases map:1000", "id"),
            ("job 1 a hadoop 0 4 phases map:1000", "platform"),
            ("job 1 a mapreduce noon 4 phases map:1000", "submit_ms"),
            ("job 1 a mapreduce 0 lots phases map:1000", "demand"),
            ("job 1 a mapreduce 0 4 stages map:1000", "phases"),
            ("job 1 a mapreduce 0 4 phases shuffle:1000", "phase kind"),
            ("job 1 a mapreduce 0 4 phases map1000", "phase token"),
            ("job 1 a mapreduce 0 4 phases map:1000,", "duration"),
            ("job 1 a mapreduce 0 4", "phases"),
            ("job 1 a mapreduce 0", "demand"),
            ("job 1 a", "platform"),
        ] {
            let e = from_trace(row).unwrap_err();
            assert!(e.contains(needle), "`{row}` error `{e}` lacks `{needle}`");
            assert!(e.contains("line 1"), "`{row}` error `{e}` lacks a line number");
        }
    }

    #[test]
    fn hostile_names_render_reparseable() {
        // Names with whitespace / '#' are legal in JobSpec but would break
        // the line format; rendering sanitizes them so the round trip
        // never produces an unparseable trace.
        let specs = vec![
            JobSpec {
                id: 1,
                name: "my job #7".into(),
                platform: Platform::MapReduce,
                submit_ms: 0,
                demand: Demand::scalar(2),
                phases: vec![PhaseSpec::new(PhaseKind::Map, &[1_000, 2_000])],
            },
            JobSpec {
                id: 2,
                name: String::new(),
                platform: Platform::Spark,
                submit_ms: 500,
                demand: Demand::scalar(1),
                phases: vec![PhaseSpec::new(PhaseKind::SparkStage, &[3_000])],
            },
        ];
        let text = to_trace(&specs);
        let back = from_trace(&text).expect("sanitized trace must re-parse");
        assert_eq!(back[0].name, "my_job__7");
        assert_eq!(back[1].name, "_");
        // Everything except the name survives exactly.
        assert_eq!(
            (back[0].id, back[0].demand, &back[0].phases),
            (1, Demand::scalar(2), &specs[0].phases)
        );
        assert_eq!((back[1].id, back[1].submit_ms), (2, 500));
    }

    #[test]
    fn parse_render_parse_is_a_fixed_point() {
        // After one render the text representation is stable: rendering
        // what was parsed reproduces the same bytes, for generated and
        // hostile-name workloads alike.
        let mut specs = generate(6, WorkloadMix::Mixed, 0.4, 1_500, 7);
        specs[0].name = "two words".into();
        specs[1].name = "trailing#comment".into();
        let text1 = to_trace(&specs);
        let parsed = from_trace(&text1).unwrap();
        let text2 = to_trace(&parsed);
        assert_eq!(text1, text2, "render is not a fixed point of parse∘render");
        assert_eq!(from_trace(&text2).unwrap(), parsed);
    }

    #[test]
    fn vector_demands_roundtrip() {
        // Hand-written vector token parses, and rendering is a fixed point.
        let specs = from_trace(
            "job 1 fatjoin spark 4000 4x12 phases stage:9000,9000,8000,7000\n\
             job 2 thin mapreduce 5000 3 phases map:1000,1000,1000\n",
        )
        .unwrap();
        assert_eq!(specs[0].demand, Demand::new(4, 12));
        assert_eq!(specs[0].demand.mem_per_container(), 3);
        assert_eq!(specs[1].demand, Demand::scalar(3));
        let text = to_trace(&specs);
        assert!(text.contains(" 4x12 "), "vector demand must render as cpu x mem:\n{text}");
        assert_eq!(from_trace(&text).unwrap(), specs);
        assert_eq!(to_trace(&from_trace(&text).unwrap()), text);
        // A vector demand too narrow for its widest phase is rejected with
        // the offending axis named (JobSpec::validate).
        let e = from_trace("job 1 a spark 0 2x9 phases stage:1,1,1").unwrap_err();
        assert!(e.contains("cpu"), "axis missing from `{e}`");
    }

    #[test]
    fn parses_checked_in_fixture() {
        // Compile-time include keeps the fixture path valid wherever the
        // test binary runs from.
        let text = include_str!("../../tests/fixtures/workload.trace");
        let specs = from_trace(text).expect("fixture trace must parse");
        assert_eq!(specs.len(), 4);
        assert_eq!(specs[0].name, "wordcount");
        assert_eq!(specs[0].platform, Platform::MapReduce);
        assert_eq!(specs[0].phases[0].tasks.len(), 3);
        assert_eq!(specs[2].platform, Platform::Spark);
        assert_eq!(specs[2].phases.len(), 3, "inline comment must not eat phases");
        assert_eq!(specs[3].submit_ms, 7_500);
        for s in &specs {
            s.validate().expect("fixture specs must be valid");
        }
        // One render is a fixed point for the fixture too.
        let rendered = to_trace(&specs);
        assert_eq!(from_trace(&rendered).unwrap(), specs);
        assert_eq!(to_trace(&from_trace(&rendered).unwrap()), rendered);
    }
}
