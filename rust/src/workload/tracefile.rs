//! Workload trace files: import/export job specs as a line-oriented text
//! format, so experiments can run recorded traces (the paper's evaluation
//! methodology) rather than only generated mixes.
//!
//! Format (one job per line, `#` comments):
//!
//! ```text
//! job <id> <name> <platform> <submit_ms> <demand> phases <kind>:<ms>,<ms>... [<kind>:...]
//! job 1 wordcount mapreduce 0 4 phases map:28000,27500,7000 reduce:16000
//! ```

use crate::jobs::{JobSpec, PhaseKind, PhaseSpec, Platform, TaskSpec};
use crate::util::Time;

/// Serialize specs to the trace format.
pub fn to_trace(specs: &[JobSpec]) -> String {
    let mut out = String::from("# dress workload trace v1\n");
    for s in specs {
        out.push_str(&format!(
            "job {} {} {} {} {} phases",
            s.id, s.name, s.platform, s.submit_ms, s.demand
        ));
        for p in &s.phases {
            let kind = match p.kind {
                PhaseKind::Map => "map",
                PhaseKind::Reduce => "reduce",
                PhaseKind::SparkStage => "stage",
            };
            let durs: Vec<String> =
                p.tasks.iter().map(|t| t.duration_ms.to_string()).collect();
            out.push_str(&format!(" {kind}:{}", durs.join(",")));
        }
        out.push('\n');
    }
    out
}

/// Parse a trace. Errors carry 1-based line numbers.
pub fn from_trace(text: &str) -> Result<Vec<JobSpec>, String> {
    let mut specs = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let err = |m: &str| format!("line {}: {m}", ln + 1);
        let mut it = line.split_whitespace();
        if it.next() != Some("job") {
            return Err(err("expected `job`"));
        }
        let id: u32 = it
            .next()
            .ok_or_else(|| err("missing id"))?
            .parse()
            .map_err(|e| err(&format!("id: {e}")))?;
        let name = it.next().ok_or_else(|| err("missing name"))?.to_string();
        let platform = match it.next().ok_or_else(|| err("missing platform"))? {
            "mapreduce" => Platform::MapReduce,
            "spark" => Platform::Spark,
            other => return Err(err(&format!("unknown platform `{other}`"))),
        };
        let submit_ms: Time = it
            .next()
            .ok_or_else(|| err("missing submit_ms"))?
            .parse()
            .map_err(|e| err(&format!("submit_ms: {e}")))?;
        let demand: u32 = it
            .next()
            .ok_or_else(|| err("missing demand"))?
            .parse()
            .map_err(|e| err(&format!("demand: {e}")))?;
        if it.next() != Some("phases") {
            return Err(err("expected `phases`"));
        }
        let mut phases = Vec::new();
        for tok in it {
            let (kind_s, durs_s) = tok
                .split_once(':')
                .ok_or_else(|| err(&format!("bad phase token `{tok}`")))?;
            let kind = match kind_s {
                "map" => PhaseKind::Map,
                "reduce" => PhaseKind::Reduce,
                "stage" => PhaseKind::SparkStage,
                other => return Err(err(&format!("unknown phase kind `{other}`"))),
            };
            let tasks: Vec<TaskSpec> = durs_s
                .split(',')
                .map(|d| {
                    d.parse::<Time>()
                        .map(|duration_ms| TaskSpec { duration_ms })
                        .map_err(|e| err(&format!("duration `{d}`: {e}")))
                })
                .collect::<Result<_, _>>()?;
            phases.push(PhaseSpec { kind, tasks });
        }
        let spec = JobSpec { id, name, platform, submit_ms, demand, phases };
        spec.validate().map_err(|e| err(&e))?;
        specs.push(spec);
    }
    Ok(specs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate, WorkloadMix};

    #[test]
    fn roundtrip_generated_workload() {
        let specs = generate(8, WorkloadMix::Mixed, 0.3, 2_000, 42);
        let text = to_trace(&specs);
        let back = from_trace(&text).unwrap();
        assert_eq!(specs, back);
    }

    #[test]
    fn parses_hand_written_trace() {
        let specs = from_trace(
            "# comment\n\
             job 1 wordcount mapreduce 0 4 phases map:28000,27500,7000 reduce:16000\n\
             job 2 pagerank spark 5000 8 phases stage:12000,12800 stage:9000\n",
        )
        .unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].phases.len(), 2);
        assert_eq!(specs[0].phases[0].tasks.len(), 3);
        assert_eq!(specs[1].platform, Platform::Spark);
        assert_eq!(specs[1].submit_ms, 5_000);
    }

    #[test]
    fn errors_carry_line_numbers() {
        assert!(from_trace("nope").unwrap_err().contains("line 1"));
        assert!(from_trace("\njob x").unwrap_err().contains("line 2"));
        assert!(from_trace("job 1 a mapreduce 0 4 phases map:abc")
            .unwrap_err()
            .contains("duration"));
        // invalid spec (no phases) rejected via validate()
        assert!(from_trace("job 1 a mapreduce 0 4 phases").is_err());
    }
}
