//! Experiment workload generation: randomized benchmark mixes with a target
//! small-job fraction and fixed inter-arrival gap (paper: jobs submitted
//! one-by-one, 5 s apart), plus the hand-built Fig. 1 motivating example.

use super::hibench::{build_job, Benchmark};
use crate::jobs::{Demand, JobSpec, PhaseKind, PhaseSpec, Platform};
use crate::util::rng::{Rng, ZipfSampler};
use crate::util::Time;

/// Which platform mix to generate (paper §V.A.2's three combinations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadMix {
    MapReduce,
    Spark,
    Mixed,
}

impl WorkloadMix {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "mapreduce" => Ok(WorkloadMix::MapReduce),
            "spark" => Ok(WorkloadMix::Spark),
            "mixed" => Ok(WorkloadMix::Mixed),
            other => Err(format!("unknown platform mix `{other}`")),
        }
    }
}

/// Largest container request the generator emits.  The paper's biggest jobs
/// request ~75% of the 40-container cluster; capping below capacity keeps
/// gang admission livelock-free under every scheduler (a demand above the
/// DRESS LD pool quota could otherwise never start).
pub const DEMAND_CAP: u32 = 30;

/// Generate `n` jobs with ~`small_frac` small-demand jobs, submitted
/// `arrival_ms` apart. Deterministic per seed.
pub fn generate(
    n: u32,
    mix: WorkloadMix,
    small_frac: f64,
    arrival_ms: Time,
    seed: u64,
) -> Vec<JobSpec> {
    let mut rng = Rng::new(seed);
    // Pre-plan which job indices are small so the fraction is exact-ish
    // (round(n * frac)), then shuffle their positions.
    let n_small = ((n as f64) * small_frac).round() as u32;
    let mut smalls: Vec<bool> = (0..n).map(|i| i < n_small).collect();
    rng.shuffle(&mut smalls);

    (0..n)
        .map(|i| {
            let platform = match mix {
                WorkloadMix::MapReduce => Platform::MapReduce,
                WorkloadMix::Spark => Platform::Spark,
                WorkloadMix::Mixed => {
                    if rng.chance(0.5) {
                        Platform::MapReduce
                    } else {
                        Platform::Spark
                    }
                }
            };
            let small = smalls[i as usize];
            let bench = pick_benchmark(&mut rng, platform, small);
            // Paper-scale congestion: 20 jobs on 40 containers with ~1000 s
            // makespan needs sizeable datasets (large jobs dominate work).
            let size = if small { rng.range_f64(0.5, 1.0) } else { rng.range_f64(1.2, 2.6) };
            let mut spec = build_job(
                i + 1,
                bench,
                platform,
                small,
                i as Time * arrival_ms,
                size,
                &mut rng,
            );
            spec.demand = spec.demand.min_each(Demand::scalar(DEMAND_CAP));
            spec
        })
        .collect()
}

/// One heavy-tailed phase for [`congested_burst`].
fn burst_phase(rng: &mut Rng, kind: PhaseKind, w: u32) -> PhaseSpec {
    let durs: Vec<Time> = (0..w)
        .map(|_| (rng.lognormal(2_000.0, 0.8) as Time).max(200))
        .collect();
    PhaseSpec::new(kind, &durs)
}

fn pick_benchmark(rng: &mut Rng, platform: Platform, small: bool) -> Benchmark {
    let pool: Vec<Benchmark> = Benchmark::ALL
        .iter()
        .copied()
        .filter(|b| b.supports(platform))
        .filter(|b| !small || b.naturally_small() || matches!(b, Benchmark::WordCount | Benchmark::Scan | Benchmark::Join | Benchmark::KMeans | Benchmark::LogisticRegression))
        .collect();
    pool[rng.index(pool.len())]
}

/// At-scale congestion scenario for throughput benches: `n` jobs (10k+
/// supported) arriving in a tight burst with heavy-tailed demands and
/// durations.
///
/// * **Demands** are Zipf-distributed over `1..=DEMAND_CAP` (exponent 1.1):
///   most jobs ask for a handful of containers, a heavy tail asks for a
///   large cluster fraction — the regime where head-of-line blocking and
///   the DRESS reserve actually matter (cf. Psychas & Ghaderi, random
///   resource requirements at deep queues).
/// * **Durations** are log-normal (median `2 s`, σ = 0.8), long-tailed like
///   real YARN task runtimes.
/// * **Arrivals** are exponential with mean `arrival_mean_ms` (Poisson
///   burst), so queue depth grows far beyond cluster capacity.
///
/// Jobs are single-phase (tasks == demand) with a 25% chance of a second,
/// half-width phase — enough structure to exercise barriers without
/// inflating event counts. Deterministic per seed.
pub fn congested_burst(n: u32, arrival_mean_ms: Time, seed: u64) -> Vec<JobSpec> {
    let mut rng = Rng::new(seed ^ 0xB0B5_7000);
    // One weight table for all n demand draws (bit-identical stream to the
    // per-draw `Rng::zipf`, minus its O(DEMAND_CAP) rebuild every job).
    let zipf = ZipfSampler::new(DEMAND_CAP as usize, 1.1);
    let mut submit: Time = 0;
    (0..n)
        .map(|i| {
            let demand = zipf.draw(&mut rng) as u32;
            let width = demand.max(1);
            let mut phases = vec![burst_phase(&mut rng, PhaseKind::Map, width)];
            if rng.chance(0.25) {
                phases.push(burst_phase(&mut rng, PhaseKind::Reduce, (width / 2).max(1)));
            }
            let gap = (-rng.next_f64().max(1e-12).ln() * arrival_mean_ms as f64) as Time;
            submit += gap;
            JobSpec {
                id: i + 1,
                name: format!("burst-{}", i + 1),
                platform: if i % 2 == 0 { Platform::MapReduce } else { Platform::Spark },
                submit_ms: submit,
                demand: Demand::scalar(demand),
                phases,
            }
        })
        .collect()
}

/// [`congested_burst`] with true *vector* demands: container counts are
/// Zipf-distributed as before, and each job additionally draws a
/// stochastic memory demand — a per-job multiplier in `1..=4` of its
/// container count, plus sub-container jitter so per-container footprints
/// exercise the round-up path (`Demand::mem_per_container`).
///
/// The RNG stream is salted differently from every other preset, so the
/// same seed yields independent draws here, in [`congested_burst`], and
/// in the engine (isolated-stream discipline, docs/RESOURCES.md).
/// Deterministic per seed.
pub fn congested_burst_vec(n: u32, arrival_mean_ms: Time, seed: u64) -> Vec<JobSpec> {
    let mut rng = Rng::new(seed ^ 0xB0B5_7EC0);
    let zipf = ZipfSampler::new(DEMAND_CAP as usize, 1.1);
    let mut submit: Time = 0;
    (0..n)
        .map(|i| {
            let cpu = (zipf.draw(&mut rng) as u32).max(1);
            // Memory ≥ cpu keeps every phase (width == cpu) legal on both
            // axes under JobSpec::validate's vector width check.
            let mult = 1 + rng.index(4) as u32;
            let jitter = rng.index(cpu as usize) as u32;
            let demand = Demand::new(cpu, cpu * mult + jitter);
            let width = cpu;
            let mut phases = vec![burst_phase(&mut rng, PhaseKind::Map, width)];
            if rng.chance(0.25) {
                phases.push(burst_phase(&mut rng, PhaseKind::Reduce, (width / 2).max(1)));
            }
            let gap = (-rng.next_f64().max(1e-12).ln() * arrival_mean_ms as f64) as Time;
            submit += gap;
            JobSpec {
                id: i + 1,
                name: format!("burst-vec-{}", i + 1),
                platform: if i % 2 == 0 { Platform::MapReduce } else { Platform::Spark },
                submit_ms: submit,
                demand,
                phases,
            }
        })
        .collect()
}

/// [`congested_burst_vec`] with **per-task** memory jitter: on top of the
/// per-job multiplier and sub-container jitter, every task of the map
/// phase draws its own `0..=2` extra memory units, summed into the job's
/// memory demand.  This widens the spread of `mem_per_container()`
/// footprints well beyond the per-job draw, which is what federated
/// `least-load`/`by-category` routing needs to differentiate cells on.
///
/// A separate preset (CLI `burst-vec-jitter`) rather than a flag on
/// [`congested_burst_vec`]: the extra draws shift the shared
/// `seed ^ 0xB0B5_7EC0` stream, and the existing `burst-vec` goldens must
/// stay bit-stable.  Deterministic per seed.
pub fn congested_burst_vec_jitter(n: u32, arrival_mean_ms: Time, seed: u64) -> Vec<JobSpec> {
    let mut rng = Rng::new(seed ^ 0xB0B5_7EC0);
    let zipf = ZipfSampler::new(DEMAND_CAP as usize, 1.1);
    let mut submit: Time = 0;
    (0..n)
        .map(|i| {
            let cpu = (zipf.draw(&mut rng) as u32).max(1);
            let mult = 1 + rng.index(4) as u32;
            let jitter = rng.index(cpu as usize) as u32;
            // Per-task jitter: one draw per map task, summed so the
            // job-level vector stays the single source of truth (mem >=
            // cpu still holds, keeping every phase width legal).
            let task_jitter: u32 = (0..cpu).map(|_| rng.index(3) as u32).sum();
            let demand = Demand::new(cpu, cpu * mult + jitter + task_jitter);
            let width = cpu;
            let mut phases = vec![burst_phase(&mut rng, PhaseKind::Map, width)];
            if rng.chance(0.25) {
                phases.push(burst_phase(&mut rng, PhaseKind::Reduce, (width / 2).max(1)));
            }
            let gap = (-rng.next_f64().max(1e-12).ln() * arrival_mean_ms as f64) as Time;
            submit += gap;
            JobSpec {
                id: i + 1,
                name: format!("burst-vec-jitter-{}", i + 1),
                platform: if i % 2 == 0 { Platform::MapReduce } else { Platform::Spark },
                submit_ms: submit,
                demand,
                phases,
            }
        })
        .collect()
}

/// The paper's Fig. 1 motivating workload: 6-container cluster, 4 jobs
/// submitted 1 s apart — J1 (R3, L10), J2 (R4, L20), J3 (R2, L5),
/// J4 (R2, L8).  Single-phase jobs with uniform task lengths.
pub fn motivating_example() -> Vec<JobSpec> {
    let mk = |id: u32, submit_s: u64, r: u32, len_s: u64| JobSpec {
        id,
        name: format!("fig1-j{id}"),
        platform: Platform::MapReduce,
        submit_ms: submit_s * 1_000,
        demand: Demand::scalar(r),
        phases: vec![PhaseSpec::new(
            PhaseKind::Map,
            &vec![len_s * 1_000; r as usize],
        )],
    };
    vec![mk(1, 0, 3, 10), mk(2, 1, 4, 20), mk(3, 2, 2, 5), mk(4, 3, 2, 8)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_count_and_arrivals() {
        let jobs = generate(20, WorkloadMix::Mixed, 0.3, 5_000, 42);
        assert_eq!(jobs.len(), 20);
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.id, i as u32 + 1);
            assert_eq!(j.submit_ms, i as Time * 5_000);
            j.validate().unwrap();
        }
    }

    #[test]
    fn small_fraction_respected() {
        let jobs = generate(20, WorkloadMix::Mixed, 0.3, 5_000, 7);
        let small = jobs.iter().filter(|j| j.demand.cpu <= 4).count();
        assert!(small >= 6, "expected >= 6 small jobs, got {small}");
    }

    #[test]
    fn platform_mixes() {
        let mr = generate(10, WorkloadMix::MapReduce, 0.3, 5_000, 1);
        assert!(mr.iter().all(|j| j.platform == Platform::MapReduce));
        let sp = generate(10, WorkloadMix::Spark, 0.3, 5_000, 1);
        assert!(sp.iter().all(|j| j.platform == Platform::Spark));
        let mix = generate(30, WorkloadMix::Mixed, 0.3, 5_000, 1);
        assert!(mix.iter().any(|j| j.platform == Platform::MapReduce));
        assert!(mix.iter().any(|j| j.platform == Platform::Spark));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(12, WorkloadMix::Mixed, 0.25, 5_000, 99);
        let b = generate(12, WorkloadMix::Mixed, 0.25, 5_000, 99);
        assert_eq!(a, b);
        let c = generate(12, WorkloadMix::Mixed, 0.25, 5_000, 100);
        assert_ne!(a, c);
    }

    #[test]
    fn motivating_example_matches_fig1() {
        let jobs = motivating_example();
        assert_eq!(jobs.len(), 4);
        assert_eq!(jobs[0].demand, Demand::scalar(3));
        assert_eq!(jobs[1].demand, Demand::scalar(4));
        assert_eq!(jobs[0].critical_path_ms(), 10_000);
        assert_eq!(jobs[1].critical_path_ms(), 20_000);
        assert_eq!(jobs[3].submit_ms, 3_000);
    }

    #[test]
    fn congested_burst_is_heavy_tailed_and_deterministic() {
        let jobs = congested_burst(500, 100, 42);
        assert_eq!(jobs.len(), 500);
        for j in &jobs {
            j.validate().unwrap();
            assert!((1..=DEMAND_CAP).contains(&j.demand.cpu));
            assert!(j.demand.is_uniform(), "scalar preset must stay uniform");
        }
        // Arrivals are a non-decreasing burst.
        assert!(jobs.windows(2).all(|w| w[0].submit_ms <= w[1].submit_ms));
        // Zipf head (many small demands) and tail (some near-cap demands).
        let small = jobs.iter().filter(|j| j.demand.cpu <= 3).count();
        let large = jobs.iter().filter(|j| j.demand.cpu >= 15).count();
        assert!(small * 5 > jobs.len() * 2, "zipf head too thin: {small}/500");
        assert!(large > 0, "zipf tail missing");
        // Deterministic per seed, distinct across seeds.
        assert_eq!(congested_burst(500, 100, 42), jobs);
        assert_ne!(congested_burst(500, 100, 43), jobs);
    }

    #[test]
    fn congested_burst_vec_draws_vector_demands() {
        let jobs = congested_burst_vec(300, 100, 42);
        assert_eq!(jobs.len(), 300);
        for j in &jobs {
            j.validate().unwrap();
            assert!((1..=DEMAND_CAP).contains(&j.demand.cpu));
            assert!(j.demand.mem >= j.demand.cpu, "mem axis must cover every task");
        }
        // The memory draws actually vary: some jobs are non-uniform, and
        // some footprints exceed one unit per container.
        assert!(jobs.iter().any(|j| !j.demand.is_uniform()), "no vector demands drawn");
        assert!(
            jobs.iter().any(|j| j.demand.mem_per_container() > 1),
            "no fat containers drawn"
        );
        // Deterministic per seed, distinct across seeds, and on a stream
        // independent from the scalar burst preset.
        assert_eq!(congested_burst_vec(300, 100, 42), jobs);
        assert_ne!(congested_burst_vec(300, 100, 43), jobs);
        let scalar = congested_burst(300, 100, 42);
        assert!(
            jobs.iter().zip(&scalar).any(|(a, b)| a.demand.cpu != b.demand.cpu),
            "vector preset must not reuse the scalar preset's RNG stream"
        );
    }

    #[test]
    fn congested_burst_vec_jitter_widens_footprints_without_touching_base() {
        let jobs = congested_burst_vec_jitter(300, 100, 42);
        assert_eq!(jobs.len(), 300);
        for j in &jobs {
            j.validate().unwrap();
            assert!((1..=DEMAND_CAP).contains(&j.demand.cpu));
            assert!(j.demand.mem >= j.demand.cpu, "mem axis must cover every task");
        }
        assert!(jobs.iter().any(|j| j.demand.mem_per_container() > 1));
        // Deterministic per seed, distinct across seeds.
        assert_eq!(congested_burst_vec_jitter(300, 100, 42), jobs);
        assert_ne!(congested_burst_vec_jitter(300, 100, 43), jobs);
        // The base preset is untouched: same seed, different draws (the
        // per-task jitter shifts the stream), and the base's own golden
        // (congested_burst_vec_draws_vector_demands) still pins its bytes.
        let base = congested_burst_vec(300, 100, 42);
        assert!(
            jobs.iter().zip(&base).any(|(a, b)| a.demand != b.demand),
            "jitter preset must not collapse into the base preset"
        );
    }

    #[test]
    fn mix_parse() {
        assert_eq!(WorkloadMix::parse("mixed").unwrap(), WorkloadMix::Mixed);
        assert!(WorkloadMix::parse("nope").is_err());
    }
}
