//! Quickstart: run one congested 20-job mixed workload under DRESS and the
//! Capacity baseline, and print the paper's headline metrics.
//!
//!     cargo run --release --example quickstart

use dress::config::{ExperimentConfig, SchedKind};
use dress::expt::run_pair;
use dress::metrics::SchedulerSummary;
use dress::report;
use dress::workload::{generate, WorkloadMix};

fn main() {
    let cfg = ExperimentConfig::default(); // 5 nodes x 8 containers, paper params
    let specs = generate(20, WorkloadMix::Mixed, 0.3, 5_000, 42);
    println!(
        "cluster: {} containers | workload: 20 mixed jobs, 5s arrivals, seed 42\n",
        cfg.cluster.total_containers()
    );

    let pair = run_pair(&cfg, specs, SchedKind::Capacity);

    println!(
        "{}",
        report::table2(&[
            SchedulerSummary::of("capacity", &pair.baseline.system),
            SchedulerSummary::of("dress", &pair.dress.system),
        ])
    );
    let c = &pair.comparison;
    println!("small jobs (demand <= 4): {:?}", c.small_ids);
    println!("  completion change: {:+.1}% (paper: up to -76.1%)", c.small_completion_change_pct);
    println!("  waiting change:    {:+.1}%", c.small_waiting_change_pct);
    println!("  best single job:   {:+.1}%", c.best_small_reduction_pct);
    println!("large jobs: completion change {:+.1}%", c.large_completion_change_pct);
    println!("makespan change: {:+.1}% (paper: ~stable, +0.6%)", c.makespan_change_pct);
}
