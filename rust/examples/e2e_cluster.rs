//! END-TO-END DRIVER: all three layers composing on a real workload.
//!
//! * Layer 1/2: each task executes the AOT-compiled PageRank power
//!   iteration (Pallas/JAX -> HLO text -> PJRT), loaded from
//!   `artifacts/taskwork.hlo.txt`.
//! * Layer 3: the DRESS scheduler (with its release estimator) makes
//!   real-time decisions over a worker pool; a Capacity run on the same
//!   workload gives the baseline.
//!
//! Reports the paper's headline metric — small-job completion-time
//! reduction — measured on *wall-clock* time with real compute.
//!
//!     make artifacts && cargo run --release --example e2e_cluster

use dress::config::{SchedConfig, SchedKind};
use dress::live::{run_live, LiveConfig};
use dress::util::stats;
use dress::workload::{generate, WorkloadMix};

fn main() -> dress::util::error::Result<()> {
    let art = dress::runtime::find_artifacts_dir()
        .expect("artifacts/ not found — run `make artifacts` first");
    let taskwork = art.join("taskwork.hlo.txt");
    let manifest = std::fs::read_to_string(art.join("manifest.txt"))?;
    dress::runtime::check_manifest(&manifest).expect("artifact/binary mismatch");

    // A small congested workload: 8 jobs on 6 worker containers.  Task
    // "duration" maps to PJRT work units at ~55 µs/unit (measured by
    // benches/perf_e2e.rs), so a 2 s nominal task is ~6000 real power-
    // iteration calls — enough work that containers are genuinely busy
    // and the reservation policy matters.
    let mut specs = generate(8, WorkloadMix::Mixed, 0.4, 1_500, 42);
    for s in specs.iter_mut() {
        for p in s.phases.iter_mut() {
            p.tasks.truncate(5);
            for t in p.tasks.iter_mut() {
                t.duration_ms = t.duration_ms.min(2_000);
            }
        }
        s.demand = s.demand.min_each(dress::jobs::Demand::scalar(5));
        s.phases.truncate(2);
    }
    let small_ids: Vec<u32> = specs.iter().filter(|s| s.demand.cpu <= 2).map(|s| s.id).collect();
    println!("e2e: 8 jobs / 6 containers, real PJRT compute per task; small jobs {small_ids:?}\n");

    let cfg = LiveConfig {
        workers: 6,
        hb: std::time::Duration::from_millis(50),
        units_per_sec: 3_000.0,
        max_wall: std::time::Duration::from_secs(240),
        ..Default::default()
    };

    let mut results = Vec::new();
    for kind in [SchedKind::Dress, SchedKind::Capacity] {
        let sched_cfg = SchedConfig { kind, theta: 0.34, ..Default::default() };
        let sched = dress::sched::build(&sched_cfg, cfg.workers as u32);
        let rep = run_live(&cfg, &sched_cfg, specs.clone(), sched, taskwork.to_str().unwrap())?;
        println!(
            "{:<9} makespan {:>7.2?}  tasks {}  checksum {:.3}",
            rep.scheduler, rep.makespan, rep.tasks_run, rep.checksum
        );
        for j in &rep.jobs {
            println!(
                "   J{:<2} demand {:<2} wait {:>6.2}s completion {:>6.2}s",
                j.id,
                j.demand,
                j.waiting_ms as f64 / 1000.0,
                j.completion_ms as f64 / 1000.0
            );
        }
        println!();
        results.push(rep);
    }

    let (dress_run, cap_run) = (&results[0], &results[1]);
    let mut small_changes = Vec::new();
    for (d, c) in dress_run.jobs.iter().zip(&cap_run.jobs) {
        if small_ids.contains(&d.id) {
            small_changes.push(stats::pct_change(
                c.completion_ms.max(1) as f64,
                d.completion_ms.max(1) as f64,
            ));
        }
    }
    println!(
        "HEADLINE — small-job completion change, DRESS vs Capacity: {:+.1}% \
         (paper: significant reduction, up to -76.1%)",
        stats::mean(&small_changes)
    );
    println!(
        "makespan change: {:+.1}% (paper: stable)",
        stats::pct_change(
            cap_run.makespan.as_millis() as f64,
            dress_run.makespan.as_millis() as f64
        )
    );
    Ok(())
}
