//! Parallel sweep quickstart: a DRESS-vs-baselines grid on the
//! `congested_burst` workload, fanned across cores with counting trace
//! sinks (memory stays O(active jobs) however long the runs get).
//!
//!     cargo run --release --example sweep -- [--jobs N] [--seeds K] [--njobs J]
//!
//! `--jobs 0` (the default) uses every core.  Results are ordered by grid
//! index, so the output is bit-identical for any `--jobs` value.

use dress::config::{ExperimentConfig, SchedKind};
use dress::expt::sweep::{effective_jobs, run_sweep, SweepGrid, SweepWorkload};
use dress::sim::EngineOptions;
use std::time::Instant;

fn arg(name: &str, default: u64) -> u64 {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let workers = arg("--jobs", 0) as usize;
    let n_seeds = arg("--seeds", 4).max(1);
    let njobs = arg("--njobs", 300).max(1) as u32;

    let grid = SweepGrid {
        base: ExperimentConfig::default(),
        seeds: (0..n_seeds).map(|i| 42 + i).collect(),
        scheds: vec![SchedKind::Fifo, SchedKind::Fair, SchedKind::Capacity, SchedKind::Dress],
        workloads: vec![SweepWorkload::CongestedBurst { n: njobs, arrival_mean_ms: 100 }],
        // Counting sinks: every run observes all tasks/transitions but
        // retains none — the bounded-memory mode for big sweeps.
        opts: EngineOptions::throughput(),
    };
    println!(
        "sweep: {} seeds x {} schedulers x congested_burst({njobs}) = {} runs on {} workers\n",
        grid.seeds.len(),
        grid.scheds.len(),
        grid.len(),
        effective_jobs(workers)
    );

    let t0 = Instant::now();
    let results = run_sweep(&grid, workers);
    let wall = t0.elapsed();

    // Mean makespan / waiting per scheduler across the seed axis.
    for (si, kind) in grid.scheds.iter().enumerate() {
        let rows: Vec<_> = (0..grid.seeds.len())
            .map(|k| &results[si * grid.seeds.len() + k])
            .collect();
        let mean = |f: &dyn Fn(&dress::sim::RunResult) -> f64| {
            rows.iter().map(|r| f(r)).sum::<f64>() / rows.len() as f64
        };
        println!(
            "{:<10} mean makespan {:>8.1}s  mean avg-wait {:>7.1}s  mean events {:>9.0}  retained transitions: {}",
            kind.name(),
            mean(&|r| r.system.makespan_ms as f64 / 1000.0),
            mean(&|r| r.system.avg_waiting_ms / 1000.0),
            mean(&|r| r.events as f64),
            rows.iter().map(|r| r.retained_transitions).max().unwrap()
        );
    }
    println!(
        "\n{} runs in {:.2?}: {:.1} runs/s",
        results.len(),
        wall,
        results.len() as f64 / wall.as_secs_f64().max(1e-9)
    );
}
