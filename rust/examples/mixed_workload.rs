//! Figs 10-13: the mixed MR+Spark setting swept over small-job fractions
//! (10% / 20% / 30% / 40%), DRESS vs Capacity.
//!
//!     cargo run --release --example mixed_workload [seed]

use dress::expt::mixed_setting;
use dress::report;

fn main() {
    let seed = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(42u64);
    println!("mixed workload sweep (20 jobs, seed {seed})\n");
    let paper = [-76.1, -36.2, -21.9, -23.7];
    for (i, frac) in [0.10, 0.20, 0.30, 0.40].iter().enumerate() {
        let pair = mixed_setting(*frac, seed);
        println!(
            "{}",
            report::fig_stacked_bars(
                &format!("Fig {} — {:.0}% small jobs", 10 + i, frac * 100.0),
                &pair.dress,
                &pair.baseline,
            )
        );
        println!(
            "  small-job completion change: {:+.1}%  (paper: {:+.1}%)   makespan change {:+.1}%\n",
            pair.comparison.small_completion_change_pct,
            paper[i],
            pair.comparison.makespan_change_pct,
        );
    }
}
