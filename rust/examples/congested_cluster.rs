//! The paper's Fig. 1 motivating example, plus every scheduler side by
//! side on the same 4-job / 6-container workload.
//!
//!     cargo run --release --example congested_cluster

use dress::config::{ExperimentConfig, SchedKind};
use dress::metrics::SchedulerSummary;
use dress::report;
use dress::sim::engine::run_experiment;
use dress::workload::motivating_example;

fn main() {
    println!("Fig 1 — 4 jobs on 6 containers (R3/L10, R4/L20, R2/L5, R2/L8), 1s arrivals\n");

    let r = dress::expt::fig1();
    println!("FCFS manner:  makespan {:>5.1}s  avg wait {:>5.1}s  (paper: 40s / 16s)",
        r.fcfs_makespan_s, r.fcfs_avg_wait_s);
    println!("DRESS:        makespan {:>5.1}s  avg wait {:>5.1}s  (paper rearranged: 30s / 5.75s)\n",
        r.dress_makespan_s, r.dress_avg_wait_s);

    // All five schedulers on the same workload.
    let mut rows = Vec::new();
    for kind in [
        SchedKind::Fifo,
        SchedKind::Fair,
        SchedKind::Capacity,
        SchedKind::Dress,
        SchedKind::MaxWeight,
    ] {
        let mut cfg = ExperimentConfig::default();
        cfg.cluster.nodes = 1;
        cfg.cluster.slots_per_node = 6;
        cfg.cluster.hb_ms = 500;
        cfg.sched.kind = kind;
        cfg.sched.theta = 0.4;
        cfg.sched.delta0 = 0.34;
        let res = run_experiment(&cfg, motivating_example());
        rows.push(SchedulerSummary::of(kind.name(), &res.system));
    }
    println!("{}", report::table2(&rows));
}
